//! Analytical step-time model: collective cost + compute → throughput and
//! GPU scaling efficiency (regenerates paper Tables 2 and 6).
//!
//! Each collective is priced phase by phase. A phase is a set of concurrent
//! ring schedules (all rows, all columns, …) of `steps` hops moving
//! `bytes_per_step`; its cost is `steps × hop_time(worst link class)`,
//! where the worst class and the concurrent-flow count come from the packed
//! placement (`cluster::placement`). The discrete-event simulator in
//! `simnet::event` validates this closed form hop by hop.
//!
//! [`ClusterModel::step_time`] prices the serial schedule (comm strictly
//! after compute); [`ClusterModel::overlapped_step_time`] prices the
//! bucketed backward-overlapped schedule the functional worker runs —
//! `step ≈ max(backprop tail, pipelined comm) + exposed head/tail` — so
//! the analytical path stays bridged to the functional path's behaviour
//! (its byte counters are unchanged by bucketing; see
//! `collectives::bucketed`'s conservation test).

use crate::cluster::LinkClass;

use super::compute::ComputeModel;
use super::linkmodel::{HeteroModel, LinkModel};

/// Collective algorithm, as priced by the model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Algo {
    /// Flat ring over all N ranks (paper baseline [14]).
    Ring,
    /// Grouped rings with intra-node groups (paper baseline [6]).
    Hierarchical { group: usize },
    /// The paper's 2D-torus, X horizontal × Y vertical.
    Torus { x: usize, y: usize },
    /// Recursive halving-doubling (Ying et al. [8] on TPU pods).
    HalvingDoubling,
}

impl Algo {
    pub fn name(&self) -> String {
        match self {
            Algo::Ring => "ring".into(),
            Algo::Hierarchical { group } => format!("hierarchical(g={group})"),
            Algo::Torus { x, y } => format!("torus2d({x}x{y})"),
            Algo::HalvingDoubling => "halving-doubling".into(),
        }
    }
}

/// One priced phase of a collective.
#[derive(Debug, Clone)]
pub struct PhaseCost {
    pub name: &'static str,
    pub steps: usize,
    pub bytes_per_step: f64,
    pub link: LinkClass,
    pub secs: f64,
}

/// Full collective cost breakdown.
#[derive(Debug, Clone)]
pub struct CollectiveCost {
    pub phases: Vec<PhaseCost>,
}

impl CollectiveCost {
    pub fn total_secs(&self) -> f64 {
        self.phases.iter().map(|p| p.secs).sum()
    }
}

/// The whole-cluster model: links + per-GPU compute.
#[derive(Debug, Clone)]
pub struct ClusterModel {
    pub lm: LinkModel,
    pub cm: ComputeModel,
    pub gpus_per_node: usize,
}

impl ClusterModel {
    pub fn abci_v100() -> Self {
        Self {
            lm: LinkModel::abci(),
            cm: ComputeModel::v100_resnet50(),
            gpus_per_node: 4,
        }
    }

    fn nodes(&self, n_ranks: usize) -> usize {
        n_ranks.div_ceil(self.gpus_per_node)
    }

    /// Worst link class + concurrent inter-node flows for a ring whose
    /// successive ranks differ by `stride` under packed placement.
    fn ring_link(&self, ring_len: usize, stride: usize, n_ranks: usize) -> (LinkClass, usize) {
        let g = self.gpus_per_node;
        if n_ranks <= g || ring_len == 1 {
            return (LinkClass::IntraNode, 0);
        }
        if stride >= g {
            // every hop crosses nodes; every member of a node sits in a
            // different ring, so all g send concurrently.
            (LinkClass::InterNode, g)
        } else {
            // stride < g: rings run along packed ranks. A ring of length
            // ring_len*stride <= g stays inside one node.
            if ring_len * stride <= g {
                (LinkClass::IntraNode, 0)
            } else {
                // boundary hops cross nodes; stride flows per node boundary.
                (LinkClass::InterNode, stride)
            }
        }
    }

    fn phase(
        &self,
        name: &'static str,
        steps: usize,
        bytes_per_step: f64,
        link: LinkClass,
        flows: usize,
        n_ranks: usize,
    ) -> PhaseCost {
        let secs = steps as f64
            * self
                .lm
                .hop_time(link, bytes_per_step, flows, self.nodes(n_ranks));
        PhaseCost {
            name,
            steps,
            bytes_per_step,
            link,
            secs,
        }
    }

    /// Price one sum-all-reduce of `bytes` under `algo` over `n_ranks`.
    pub fn collective_cost(&self, algo: Algo, n_ranks: usize, bytes: f64) -> CollectiveCost {
        let phases = match algo {
            Algo::Ring => {
                let (link, flows) = self.ring_link(n_ranks, 1, n_ranks);
                vec![self.phase(
                    "ring-allreduce",
                    2 * (n_ranks - 1),
                    bytes / n_ranks as f64,
                    link,
                    flows,
                    n_ranks,
                )]
            }
            Algo::Hierarchical { group } => {
                assert_eq!(n_ranks % group, 0);
                let groups = n_ranks / group;
                let (l1, f1) = self.ring_link(group, 1, n_ranks);
                let (l2, f2) = self.ring_link(groups, group, n_ranks);
                vec![
                    self.phase(
                        "intra reduce-scatter",
                        group - 1,
                        bytes / group as f64,
                        l1,
                        f1,
                        n_ranks,
                    ),
                    self.phase(
                        "inter all-reduce",
                        2 * (groups - 1),
                        // the inter ring all-reduces a chunk of bytes/group
                        // over `groups` peers -> bytes/(group·groups) per hop
                        bytes / (group * groups) as f64,
                        l2,
                        f2,
                        n_ranks,
                    ),
                    self.phase(
                        "intra all-gather",
                        group - 1,
                        bytes / group as f64,
                        l1,
                        f1,
                        n_ranks,
                    ),
                ]
            }
            Algo::HalvingDoubling => {
                assert!(n_ranks.is_power_of_two());
                let rounds = n_ranks.trailing_zeros() as usize;
                // every round's pairing spans >= gpus_per_node at scale, so
                // each is priced at the inter-node class with g flows per
                // node (all ranks exchange concurrently); round r moves
                // bytes/2^{r+1}, twice (scatter + gather).
                let (link, flows) = self.ring_link(n_ranks, self.gpus_per_node, n_ranks);
                (0..rounds)
                    .map(|r| {
                        let b = bytes / 2f64.powi(r as i32 + 1);
                        let mut p = self.phase("hd round", 2, b, link, flows, n_ranks);
                        p.name = "halving-doubling round";
                        p
                    })
                    .collect()
            }
            Algo::Torus { x, y } => {
                assert_eq!(x * y, n_ranks, "torus shape must cover the world");
                let (lh, fh) = self.ring_link(x, 1, n_ranks);
                let (lv, fv) = self.ring_link(y, x, n_ranks);
                vec![
                    self.phase(
                        "horizontal reduce-scatter",
                        x.saturating_sub(1),
                        bytes / x as f64,
                        lh,
                        fh,
                        n_ranks,
                    ),
                    self.phase(
                        "vertical all-reduce",
                        2 * y.saturating_sub(1),
                        bytes / (x * y) as f64,
                        lv,
                        fv,
                        n_ranks,
                    ),
                    self.phase(
                        "horizontal all-gather",
                        x.saturating_sub(1),
                        bytes / x as f64,
                        lh,
                        fh,
                        n_ranks,
                    ),
                ]
            }
        };
        CollectiveCost { phases }
    }
}

// NOTE on the hierarchical inter phase: the ring over `groups` peers
// all-reduces a chunk of `bytes / group`; per hop that is
// `(bytes/group) / groups`. The expression above reduces to exactly that —
// kept explicit to mirror the derivation in the paper's §2.2 comparison.

/// Wall-clock cost of one elastic-recovery event (rank death mid-phase):
/// detection latency + re-planning + replaying the aborted phase on the
/// survivors. See [`ClusterModel::recovery_time`].
#[derive(Debug, Clone)]
pub struct RecoveryCost {
    /// Worst-case failure-detection latency (the heartbeat `rank_timeout`).
    pub detect_secs: f64,
    /// Coordinator re-planning plus re-distributing the FP32 training
    /// state to the survivor mesh.
    pub replan_secs: f64,
    /// Re-running the aborted phase's steps on the degraded world.
    pub replay_secs: f64,
}

impl RecoveryCost {
    pub fn total_secs(&self) -> f64 {
        self.detect_secs + self.replan_secs + self.replay_secs
    }
}

/// Wall-clock cost of one rejoin event: a killed worker restarts, the
/// coordinator holds the phase boundary for it (`fault.rejoin_grace`), and
/// the phase replays at **restored** width. See
/// [`ClusterModel::rejoin_time`].
#[derive(Debug, Clone)]
pub struct RejoinCost {
    /// Detection plus the boundary hold: the heartbeat `rank_timeout`
    /// (worst case — a hang) plus the grace spent waiting for the
    /// replacement to dial back in.
    pub wait_secs: f64,
    /// Coordinator control work plus re-shipping the FP32 training state
    /// to the restored full-width mesh.
    pub replan_secs: f64,
    /// Re-running the aborted phase's steps — at full width, which is the
    /// point of waiting: replay math (and bytes) match the undisturbed run.
    pub replay_secs: f64,
}

impl RejoinCost {
    pub fn total_secs(&self) -> f64 {
        self.wait_secs + self.replan_secs + self.replay_secs
    }
}

/// Wall-clock cost of one coordinator crash/resume event: the whole
/// cluster idles through the coordinator's down time, the restarted
/// process replays the run journal and restores the newest snapshot, and
/// the interrupted phase replays from that snapshot's boundary. See
/// [`ClusterModel::restart_time`].
#[derive(Debug, Clone)]
pub struct RestartCost {
    /// Coordinator down time: crash-to-restart latency (supervisor /
    /// operator), during which the orphaned workers hold in their
    /// `fault.coordinator_grace` window.
    pub detect_secs: f64,
    /// Journal replay + snapshot restore + re-registering the held
    /// workers and re-shipping the restored FP32 state to full width.
    pub resume_secs: f64,
    /// Re-running the steps between the restored snapshot and the crash —
    /// the work the snapshot cadence (`[checkpoint] every_steps`) forfeits.
    pub replay_secs: f64,
}

impl RestartCost {
    pub fn total_secs(&self) -> f64 {
        self.detect_secs + self.resume_secs + self.replay_secs
    }
}

/// Wall-clock comparison of the two straggler policies over the remainder
/// of a run: **tolerate** (keep the slow rank; every synchronous step runs
/// at its pace) vs **demote** (detect it, drain it at a phase boundary,
/// finish at healthy pace on the shrunk world). See
/// [`ClusterModel::straggler_time`].
#[derive(Debug, Clone)]
pub struct StragglerCost {
    /// Keeping the straggler: all remaining steps at its pace.
    pub tolerate_secs: f64,
    /// Time to confirm the straggler: `min_samples` steps of telemetry
    /// plus the sustained-over-threshold grace — all spent at its pace,
    /// because synchrony means detection happens while being slowed.
    pub detect_secs: f64,
    /// Boundary re-plan: control work + redistributing the FP32 state on
    /// the shrunk world (same shape as a recovery re-plan, but with no
    /// aborted steps to replay — demotion drains at a boundary).
    pub replan_secs: f64,
    /// The steps left after detection, at healthy pace on the survivors
    /// (global batch preserved, per-worker batch stepped up).
    pub healthy_secs: f64,
}

impl StragglerCost {
    /// Total wall of the demote policy.
    pub fn demote_secs(&self) -> f64 {
        self.detect_secs + self.replan_secs + self.healthy_secs
    }

    /// Whether demoting beats tolerating for this remainder.
    pub fn demotion_pays(&self) -> bool {
        self.demote_secs() < self.tolerate_secs
    }
}

/// One synchronous step on a heterogeneous cluster: per-rank step times
/// under a [`HeteroModel`], and the tax the slowest rank levies on
/// everyone. See [`ClusterModel::hetero_step_time`].
#[derive(Debug, Clone)]
pub struct HeteroStep {
    /// Median rank's step time — the pace a homogeneous cluster of the
    /// typical machine would run at.
    pub median_secs: f64,
    /// The slowest rank's step time — under synchronous SGD, *the* step
    /// time: every collective waits for it.
    pub slowest_secs: f64,
    /// Which rank sets the pace.
    pub slowest_rank: usize,
    /// `slowest − median`: the per-step wall-clock cost of synchrony on
    /// this cluster (what straggler mitigation can win back).
    pub straggler_tax_secs: f64,
}

/// Coordinator-side control latency of a re-plan (tiny JSON frames, one
/// round trip per rank) — shared by the recovery and rejoin models.
const REPLAN_CONTROL_SECS: f64 = 0.05;

/// Per-step time breakdown for a full training step.
#[derive(Debug, Clone)]
pub struct StepBreakdown {
    pub compute_secs: f64,
    pub grad_comm_secs: f64,
    pub bn_comm_secs: f64,
}

impl StepBreakdown {
    pub fn total_secs(&self) -> f64 {
        self.compute_secs + self.grad_comm_secs + self.bn_comm_secs
    }
}

/// Per-step breakdown under the bucketed, backward-overlapped schedule
/// (the functional path's `bucket_bytes` pipeline).
#[derive(Debug, Clone)]
pub struct OverlappedStep {
    /// Forward + backward compute (unchanged by the pipeline).
    pub compute_secs: f64,
    /// Total gradient-collective time summed over buckets. Bucketing
    /// multiplies the message count, so this is ≥ the monolithic
    /// `grad_comm_secs` — the pipeline wins by *hiding* it, not by
    /// shrinking it.
    pub grad_comm_secs: f64,
    /// BN-stat collective (not overlapped; runs after the last gradient).
    pub bn_comm_secs: f64,
    /// Communication that extends the step beyond the compute span:
    /// `max(0, pipeline drain − compute) + bn`.
    pub exposed_comm_secs: f64,
    /// `max(compute, pipeline drain) + bn` — the overlapped step time.
    pub total_secs: f64,
}

impl ClusterModel {
    /// Step time with the gradient all-reduce pipelined against the
    /// backward pass in `n_buckets` buckets (paper §2.2 / the follow-up
    /// 1903.12650's comm/compute overlap), mirroring the functional
    /// worker: bucket *i* becomes ready as backprop retires its layers and
    /// its reduction runs concurrently with the rest of the backward pass.
    ///
    /// Model: forward ≈ 1/3 of step compute, backward ≈ 2/3 (the usual
    /// 1:2 flop split); bucket `i` of `k` is ready at
    /// `fwd + bwd·(i+1)/k`; each bucket's reduction costs one collective
    /// over `grad_bytes / k`; reductions run back-to-back on the wire
    /// (`drain_{i} = max(ready_i, drain_{i-1}) + d`). `n_buckets = 1`
    /// degenerates to the serial [`Self::step_time`] exactly. The byte
    /// volume is conserved — bucketing repartitions the same
    /// `grad_bytes`, matching the functional path's wire counters.
    pub fn overlapped_step_time(
        &self,
        algo: Algo,
        n_ranks: usize,
        per_worker_batch: usize,
        grad_bytes: f64,
        bn_bytes: f64,
        n_buckets: usize,
    ) -> OverlappedStep {
        let k = n_buckets.max(1);
        let compute = self.cm.step_seconds(per_worker_batch);
        let fwd = compute / 3.0;
        let bwd = compute - fwd;
        let per_bucket = self
            .collective_cost(algo, n_ranks, grad_bytes / k as f64)
            .total_secs();
        let bn = self.collective_cost(algo, n_ranks, bn_bytes).total_secs();
        let mut drain = 0.0f64;
        for i in 0..k {
            let ready = fwd + bwd * (i as f64 + 1.0) / k as f64;
            drain = drain.max(ready) + per_bucket;
        }
        OverlappedStep {
            compute_secs: compute,
            grad_comm_secs: per_bucket * k as f64,
            bn_comm_secs: bn,
            exposed_comm_secs: (drain - compute).max(0.0) + bn,
            total_secs: drain.max(compute) + bn,
        }
    }

    /// Price one elastic-recovery event: the wall-clock a rank death costs
    /// the run under the coordinator's detect → re-plan → replay sequence.
    ///
    /// - **detect**: the heartbeat monitor cannot declare a rank dead
    ///   before its beat is `rank_timeout` stale (a crashed rank is caught
    ///   faster via the abort flag, so this is the worst case — a hang).
    /// - **re-plan**: coordinator control work (a small constant) plus one
    ///   full-state broadcast-class collective on the survivors: the FP32
    ///   parameters + momenta the replay attempt re-distributes, priced as
    ///   an all-reduce of `4 × grad_bytes` (two FP32 tensors vs one FP16).
    /// - **replay**: the aborted phase re-runs from its boundary state —
    ///   `replay_steps` full steps on the degraded world.
    pub fn recovery_time(
        &self,
        algo_after: Algo,
        survivors: usize,
        per_worker_batch: usize,
        grad_bytes: f64,
        bn_bytes: f64,
        replay_steps: usize,
        rank_timeout_secs: f64,
    ) -> RecoveryCost {
        let state_bytes = 4.0 * grad_bytes; // fp32 params + momenta vs fp16 grads
        let replan_secs = REPLAN_CONTROL_SECS
            + self
                .collective_cost(algo_after, survivors, state_bytes)
                .total_secs();
        let step = self
            .step_time(
                algo_after,
                survivors,
                per_worker_batch,
                grad_bytes,
                bn_bytes,
            )
            .total_secs();
        RecoveryCost {
            detect_secs: rank_timeout_secs,
            replan_secs,
            replay_secs: replay_steps as f64 * step,
        }
    }

    /// Price one rejoin event: like [`Self::recovery_time`], but the
    /// coordinator spends up to `rejoin_grace_secs` holding the phase
    /// boundary for the restarted worker and then replays at the restored
    /// **full** width (`ranks`). Rejoin trades boundary-hold time for a
    /// replay whose arithmetic — and therefore whose final checkpoint —
    /// is identical to the undisturbed run's; shrinking to the survivors
    /// instead starts the faster degraded replay immediately. Comparing
    /// `rejoin_time(...)` against `recovery_time(...)` prices exactly that
    /// trade.
    #[allow(clippy::too_many_arguments)]
    pub fn rejoin_time(
        &self,
        algo: Algo,
        ranks: usize,
        per_worker_batch: usize,
        grad_bytes: f64,
        bn_bytes: f64,
        replay_steps: usize,
        rank_timeout_secs: f64,
        rejoin_grace_secs: f64,
    ) -> RejoinCost {
        let state_bytes = 4.0 * grad_bytes; // fp32 params + momenta vs fp16 grads
        let replan_secs = REPLAN_CONTROL_SECS
            + self.collective_cost(algo, ranks, state_bytes).total_secs();
        let step = self
            .step_time(algo, ranks, per_worker_batch, grad_bytes, bn_bytes)
            .total_secs();
        RejoinCost {
            wait_secs: rank_timeout_secs + rejoin_grace_secs,
            replan_secs,
            replay_secs: replay_steps as f64 * step,
        }
    }

    /// Price one coordinator crash/resume event: like
    /// [`Self::rejoin_time`], but the dead process is the *coordinator* —
    /// the durability tentpole's scenario. The cluster idles for
    /// `coordinator_down_secs` (the workers hold under
    /// `fault.coordinator_grace`), the restarted coordinator replays the
    /// journal and restores the newest snapshot (control work plus one
    /// full-state redistribution to the restored full-width mesh), then
    /// replays the `replay_steps` between that snapshot and the crash.
    /// Sweeping `replay_steps` against the snapshot cadence prices the
    /// `[checkpoint] every_steps` overhead/recovery trade directly.
    #[allow(clippy::too_many_arguments)]
    pub fn restart_time(
        &self,
        algo: Algo,
        ranks: usize,
        per_worker_batch: usize,
        grad_bytes: f64,
        bn_bytes: f64,
        replay_steps: usize,
        coordinator_down_secs: f64,
    ) -> RestartCost {
        let state_bytes = 4.0 * grad_bytes; // fp32 params + momenta vs fp16 grads
        let resume_secs = REPLAN_CONTROL_SECS
            + self.collective_cost(algo, ranks, state_bytes).total_secs();
        let step = self
            .step_time(algo, ranks, per_worker_batch, grad_bytes, bn_bytes)
            .total_secs();
        RestartCost {
            detect_secs: coordinator_down_secs,
            resume_secs,
            replay_secs: replay_steps as f64 * step,
        }
    }

    /// One synchronous step on a cluster whose ranks carry per-rank
    /// compute/link multipliers from a [`HeteroModel`]. Rank `r`'s own
    /// step costs `compute × compute_multiplier(r) + comm ×
    /// link_multiplier(r)`; the *synchronous* step is the slowest rank's,
    /// and `straggler_tax_secs` is what that slowest rank costs everyone
    /// per step relative to the cluster median.
    pub fn hetero_step_time(
        &self,
        algo: Algo,
        n_ranks: usize,
        per_worker_batch: usize,
        grad_bytes: f64,
        bn_bytes: f64,
        hetero: &HeteroModel,
    ) -> HeteroStep {
        let base = self.step_time(algo, n_ranks, per_worker_batch, grad_bytes, bn_bytes);
        let comm = base.grad_comm_secs + base.bn_comm_secs;
        let per_rank: Vec<f64> = (0..n_ranks)
            .map(|r| {
                base.compute_secs * hetero.compute_multiplier(r)
                    + comm * hetero.link_multiplier(r)
            })
            .collect();
        let slowest_rank = per_rank
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
            .map(|(r, _)| r)
            .unwrap_or(0);
        let slowest_secs = per_rank[slowest_rank];
        let mut sorted = per_rank;
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        let median_secs = sorted[(sorted.len() - 1) / 2];
        HeteroStep {
            median_secs,
            slowest_secs,
            slowest_rank,
            straggler_tax_secs: slowest_secs - median_secs,
        }
    }

    /// Price the straggler-defense trade for a run with `remaining_steps`
    /// left when one rank goes `slow_factor ×` slow on compute:
    ///
    /// - **tolerate**: every remaining synchronous step runs at the
    ///   straggler's pace (compute stretched, comm unchanged).
    /// - **demote**: `detect_steps` steps of telemetry plus `grace_secs`
    ///   of sustained-over-threshold confirmation (all at straggler pace),
    ///   one boundary re-plan (control + FP32 state redistribution on the
    ///   survivors — no aborted work to replay, demotion drains at a
    ///   boundary), then the rest at healthy pace on `n_ranks − 1` ranks
    ///   with the global batch preserved.
    ///
    /// Comparing the two (`StragglerCost::demotion_pays`) is the analytic
    /// form of the `[fault.straggler]` policy choice, and the
    /// heterogeneous-cluster half of the simnet roadmap item.
    #[allow(clippy::too_many_arguments)]
    pub fn straggler_time(
        &self,
        algo_full: Algo,
        algo_after: Algo,
        n_ranks: usize,
        per_worker_batch: usize,
        grad_bytes: f64,
        bn_bytes: f64,
        remaining_steps: usize,
        slow_factor: f64,
        detect_steps: usize,
        grace_secs: f64,
    ) -> StragglerCost {
        let base = self.step_time(algo_full, n_ranks, per_worker_batch, grad_bytes, bn_bytes);
        // Synchrony: the straggler's stretched compute sets everyone's pace.
        let slow_step = base.compute_secs * slow_factor.max(1.0)
            + base.grad_comm_secs
            + base.bn_comm_secs;
        let survivors = (n_ranks - 1).max(1);
        // Constant global batch: the survivors absorb the drained rank's
        // share, so their per-worker batch (and compute) steps up.
        let per_worker_after = (per_worker_batch * n_ranks).div_ceil(survivors);
        let state_bytes = 4.0 * grad_bytes; // fp32 params + momenta vs fp16 grads
        let replan_secs = REPLAN_CONTROL_SECS
            + self
                .collective_cost(algo_after, survivors, state_bytes)
                .total_secs();
        let healthy_step = self
            .step_time(algo_after, survivors, per_worker_after, grad_bytes, bn_bytes)
            .total_secs();
        let detect = detect_steps.min(remaining_steps);
        StragglerCost {
            tolerate_secs: remaining_steps as f64 * slow_step,
            detect_secs: detect as f64 * slow_step + grace_secs,
            replan_secs,
            healthy_secs: (remaining_steps - detect) as f64 * healthy_step,
        }
    }

    /// One synchronous data-parallel training step (paper §2 structure):
    /// fwd+bwd compute, FP16 gradient all-reduce, FP32 BN-stat all-reduce.
    pub fn step_time(
        &self,
        algo: Algo,
        n_ranks: usize,
        per_worker_batch: usize,
        grad_bytes: f64,
        bn_bytes: f64,
    ) -> StepBreakdown {
        StepBreakdown {
            compute_secs: self.cm.step_seconds(per_worker_batch),
            grad_comm_secs: self.collective_cost(algo, n_ranks, grad_bytes).total_secs(),
            bn_comm_secs: self.collective_cost(algo, n_ranks, bn_bytes).total_secs(),
        }
    }

    /// Cluster throughput in images/sec.
    pub fn throughput(
        &self,
        algo: Algo,
        n_ranks: usize,
        per_worker_batch: usize,
        grad_bytes: f64,
        bn_bytes: f64,
    ) -> f64 {
        let step = self.step_time(algo, n_ranks, per_worker_batch, grad_bytes, bn_bytes);
        (n_ranks * per_worker_batch) as f64 / step.total_secs()
    }

    /// GPU scaling efficiency relative to the single-node (4 GPU) run —
    /// the paper's Table 6 definition.
    pub fn scaling_efficiency(
        &self,
        algo_at: impl Fn(usize) -> Algo,
        n_ranks: usize,
        per_worker_batch: usize,
        grad_bytes: f64,
        bn_bytes: f64,
    ) -> f64 {
        let base = self.throughput(algo_at(4), 4, per_worker_batch, grad_bytes, bn_bytes);
        let thr = self.throughput(
            algo_at(n_ranks),
            n_ranks,
            per_worker_batch,
            grad_bytes,
            bn_bytes,
        );
        thr / (base * n_ranks as f64 / 4.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::best_grid;
    use crate::simnet::compute::{RESNET50_BN_BYTES_FP32, RESNET50_GRAD_BYTES_FP16};

    fn torus_at(n: usize) -> Algo {
        let (x, y) = best_grid(n);
        Algo::Torus { x, y }
    }

    #[test]
    fn torus_beats_ring_at_scale() {
        let m = ClusterModel::abci_v100();
        let bytes = RESNET50_GRAD_BYTES_FP16;
        for n in [256usize, 1024, 4096] {
            let (x, y) = best_grid(n);
            let ring = m.collective_cost(Algo::Ring, n, bytes).total_secs();
            let torus = m.collective_cost(Algo::Torus { x, y }, n, bytes).total_secs();
            assert!(
                torus < ring,
                "n={n}: torus {torus:.6} !< ring {ring:.6}"
            );
        }
    }

    #[test]
    fn torus_vs_hierarchical_second_phase_volume() {
        // Paper §2.2: same step structure, but the torus's second phase
        // moves X/g times less TOTAL data than hierarchical's inter phase.
        let m = ClusterModel::abci_v100();
        let bytes = RESNET50_GRAD_BYTES_FP16;
        let h = m.collective_cost(Algo::Hierarchical { group: 4 }, 1024, bytes);
        let t = m.collective_cost(Algo::Torus { x: 32, y: 32 }, 1024, bytes);
        let h_vol = h.phases[1].steps as f64 * h.phases[1].bytes_per_step;
        let t_vol = t.phases[1].steps as f64 * t.phases[1].bytes_per_step;
        // X/g = 32/4 = 8, times the step-count ratio (510/62) ≈ 8.2× total
        assert!(
            h_vol / t_vol > 6.0,
            "hier vol {h_vol:.0} vs torus vol {t_vol:.0}"
        );
        // At full ABCI scale the latency term makes the torus strictly win.
        let h4096 = m
            .collective_cost(Algo::Hierarchical { group: 4 }, 4096, bytes)
            .total_secs();
        let t4096 = m
            .collective_cost(Algo::Torus { x: 64, y: 64 }, 4096, bytes)
            .total_secs();
        assert!(t4096 < h4096, "torus {t4096:.6} !< hierarchical {h4096:.6}");
    }

    #[test]
    fn table6_shape_reproduced() {
        // Paper Table 6: (#GPUs, images/sec, efficiency%).
        let paper: &[(usize, f64, f64)] = &[
            (1024, 556_522.0, 84.75),
            (2048, 1_091_357.0, 83.10),
            (3456, 1_641_853.0, 74.08),
            (4096, 1_929_054.0, 73.44),
        ];
        let m = ClusterModel::abci_v100();
        for &(n, paper_thr, paper_eff) in paper {
            let eff = 100.0
                * m.scaling_efficiency(
                    torus_at,
                    n,
                    32,
                    RESNET50_GRAD_BYTES_FP16,
                    RESNET50_BN_BYTES_FP32,
                );
            let thr = m.throughput(
                torus_at(n),
                n,
                32,
                RESNET50_GRAD_BYTES_FP16,
                RESNET50_BN_BYTES_FP32,
            );
            // shape: within 6 efficiency points and 10% throughput
            assert!(
                (eff - paper_eff).abs() < 6.0,
                "n={n}: model eff {eff:.2}% vs paper {paper_eff}%"
            );
            assert!(
                (thr - paper_thr).abs() / paper_thr < 0.10,
                "n={n}: model thr {thr:.0} vs paper {paper_thr}"
            );
        }
    }

    #[test]
    fn efficiency_decreases_with_scale() {
        let m = ClusterModel::abci_v100();
        let effs: Vec<f64> = [1024usize, 2048, 3456, 4096]
            .iter()
            .map(|&n| {
                m.scaling_efficiency(
                    torus_at,
                    n,
                    32,
                    RESNET50_GRAD_BYTES_FP16,
                    RESNET50_BN_BYTES_FP32,
                )
            })
            .collect();
        for w in effs.windows(2) {
            assert!(w[1] <= w[0] + 1e-9, "monotone: {effs:?}");
        }
    }

    #[test]
    fn single_node_baseline_matches_paper() {
        // Table 6 first row: 4 GPUs -> 2565 images/s.
        let m = ClusterModel::abci_v100();
        let thr = m.throughput(
            Algo::Torus { x: 2, y: 2 },
            4,
            32,
            RESNET50_GRAD_BYTES_FP16,
            RESNET50_BN_BYTES_FP32,
        );
        assert!((thr - 2565.0).abs() / 2565.0 < 0.05, "thr {thr:.0}");
    }

    /// One bucket = the serial schedule, exactly: total, comm and compute
    /// all match `step_time`'s additive breakdown.
    #[test]
    fn overlapped_with_one_bucket_degenerates_to_serial() {
        let m = ClusterModel::abci_v100();
        let (x, y) = best_grid(1024);
        let algo = Algo::Torus { x, y };
        let serial = m.step_time(
            algo,
            1024,
            32,
            RESNET50_GRAD_BYTES_FP16,
            RESNET50_BN_BYTES_FP32,
        );
        let o = m.overlapped_step_time(
            algo,
            1024,
            32,
            RESNET50_GRAD_BYTES_FP16,
            RESNET50_BN_BYTES_FP32,
            1,
        );
        assert!((o.total_secs - serial.total_secs()).abs() < 1e-12);
        assert!((o.grad_comm_secs - serial.grad_comm_secs).abs() < 1e-12);
        assert!((o.exposed_comm_secs - (serial.grad_comm_secs + serial.bn_comm_secs)).abs() < 1e-12);
    }

    /// The pipeline invariants: comm is conserved-or-grown (message count
    /// went up), the step never gets slower than fully-serial comm and
    /// never faster than max(compute, comm) — and at the paper's scale a
    /// handful of buckets genuinely hides most of the gradient exchange.
    #[test]
    fn overlapped_step_bounds_and_speedup() {
        let m = ClusterModel::abci_v100();
        for n in [256usize, 1024, 4096] {
            let (x, y) = best_grid(n);
            let algo = Algo::Torus { x, y };
            let serial = m
                .step_time(algo, n, 32, RESNET50_GRAD_BYTES_FP16, RESNET50_BN_BYTES_FP32)
                .total_secs();
            for k in [2usize, 4, 8, 16] {
                let o = m.overlapped_step_time(
                    algo,
                    n,
                    32,
                    RESNET50_GRAD_BYTES_FP16,
                    RESNET50_BN_BYTES_FP32,
                    k,
                );
                assert!(o.exposed_comm_secs >= o.bn_comm_secs - 1e-15);
                assert!(o.total_secs >= o.compute_secs + o.bn_comm_secs - 1e-15);
                // pipelining never serialises more than compute + all comm
                assert!(
                    o.total_secs
                        <= o.compute_secs + o.grad_comm_secs + o.bn_comm_secs + 1e-12
                );
                // bucketing keeps the volume and adds per-message latency,
                // so total grad comm can only grow relative to monolithic
                let mono_grad = m
                    .collective_cost(algo, n, RESNET50_GRAD_BYTES_FP16)
                    .total_secs();
                assert!(o.grad_comm_secs >= mono_grad - 1e-12);
            }
            // 8 buckets at this scale: the overlapped step beats serial
            let o8 = m.overlapped_step_time(
                algo,
                n,
                32,
                RESNET50_GRAD_BYTES_FP16,
                RESNET50_BN_BYTES_FP32,
                8,
            );
            assert!(
                o8.total_secs < serial,
                "n={n}: overlapped {:.6} !< serial {serial:.6}",
                o8.total_secs
            );
        }
    }

    /// Recovery cost decomposes additively and scales with its inputs:
    /// detection is exactly the timeout, replay is linear in steps, and a
    /// bigger timeout only moves the detect term.
    #[test]
    fn recovery_time_decomposition() {
        let m = ClusterModel::abci_v100();
        let algo = torus_at(1023); // degraded world after losing 1 of 1024
        let r = m.recovery_time(
            algo,
            1023,
            32,
            RESNET50_GRAD_BYTES_FP16,
            RESNET50_BN_BYTES_FP32,
            100,
            30.0,
        );
        assert_eq!(r.detect_secs, 30.0);
        assert!(
            (r.total_secs() - (r.detect_secs + r.replan_secs + r.replay_secs)).abs() < 1e-12
        );
        // replay = steps × step_time on the degraded world, exactly
        let step = m
            .step_time(algo, 1023, 32, RESNET50_GRAD_BYTES_FP16, RESNET50_BN_BYTES_FP32)
            .total_secs();
        assert!((r.replay_secs - 100.0 * step).abs() < 1e-9);
        // re-planning ships fp32 state: strictly pricier than one fp16
        // gradient all-reduce on the same world
        let one_grad = m
            .collective_cost(algo, 1023, RESNET50_GRAD_BYTES_FP16)
            .total_secs();
        assert!(r.replan_secs > one_grad);
        // zero replay steps leaves only detect + replan
        let r0 = m.recovery_time(
            algo,
            1023,
            32,
            RESNET50_GRAD_BYTES_FP16,
            RESNET50_BN_BYTES_FP32,
            0,
            30.0,
        );
        assert_eq!(r0.replay_secs, 0.0);
        assert!(r0.total_secs() < r.total_secs());
    }

    /// A tighter rank_timeout shrinks recovery cost one-for-one; replaying
    /// a long phase dominates the bill at realistic step counts — the
    /// quantitative argument for phase-boundary (not end-of-run) recovery.
    #[test]
    fn recovery_detect_vs_replay_tradeoff() {
        let m = ClusterModel::abci_v100();
        let algo = torus_at(255);
        let fast = m.recovery_time(
            algo,
            255,
            32,
            RESNET50_GRAD_BYTES_FP16,
            RESNET50_BN_BYTES_FP32,
            50,
            1.0,
        );
        let slow = m.recovery_time(
            algo,
            255,
            32,
            RESNET50_GRAD_BYTES_FP16,
            RESNET50_BN_BYTES_FP32,
            50,
            30.0,
        );
        assert!((slow.total_secs() - fast.total_secs() - 29.0).abs() < 1e-9);
        // an epoch-scale replay (thousands of steps) dwarfs a 30 s timeout
        let epoch = m.recovery_time(
            algo,
            255,
            32,
            RESNET50_GRAD_BYTES_FP16,
            RESNET50_BN_BYTES_FP32,
            5000,
            30.0,
        );
        assert!(epoch.replay_secs > epoch.detect_secs + epoch.replan_secs);
    }

    /// Rejoin cost decomposes additively, replay is priced at *restored*
    /// width, and the grace moves only the wait term — so against
    /// `recovery_time` on the same world the whole difference is the
    /// boundary hold.
    #[test]
    fn rejoin_time_decomposition() {
        let m = ClusterModel::abci_v100();
        let algo = torus_at(1024); // full width again once the worker is back
        let r = m.rejoin_time(
            algo,
            1024,
            32,
            RESNET50_GRAD_BYTES_FP16,
            RESNET50_BN_BYTES_FP32,
            100,
            30.0,
            5.0,
        );
        assert_eq!(r.wait_secs, 35.0);
        assert!((r.total_secs() - (r.wait_secs + r.replan_secs + r.replay_secs)).abs() < 1e-12);
        // replay = steps × step_time at full width, exactly
        let step = m
            .step_time(algo, 1024, 32, RESNET50_GRAD_BYTES_FP16, RESNET50_BN_BYTES_FP32)
            .total_secs();
        assert!((r.replay_secs - 100.0 * step).abs() < 1e-9);
        // vs recovery on the same (full-width) world the grace is the
        // entire premium: rejoin trades exactly that hold for an
        // undisturbed-identical replay
        let rec = m.recovery_time(
            algo,
            1024,
            32,
            RESNET50_GRAD_BYTES_FP16,
            RESNET50_BN_BYTES_FP32,
            100,
            30.0,
        );
        assert!((r.total_secs() - rec.total_secs() - 5.0).abs() < 1e-9);
        // zero grace + zero steps leaves only detection + replan
        let r0 = m.rejoin_time(
            algo,
            1024,
            32,
            RESNET50_GRAD_BYTES_FP16,
            RESNET50_BN_BYTES_FP32,
            0,
            30.0,
            0.0,
        );
        assert_eq!(r0.replay_secs, 0.0);
        assert_eq!(r0.wait_secs, 30.0);
        assert!(r0.total_secs() < r.total_secs());
    }

    /// Coordinator crash/resume cost decomposes additively, the replay is
    /// priced at full width (the held workers all come back), and the
    /// replay term scales one-for-one with the snapshot gap — the knob
    /// `[checkpoint] every_steps` controls.
    #[test]
    fn restart_time_decomposition() {
        let m = ClusterModel::abci_v100();
        let algo = torus_at(1024);
        let r = m.restart_time(
            algo,
            1024,
            32,
            RESNET50_GRAD_BYTES_FP16,
            RESNET50_BN_BYTES_FP32,
            100,
            10.0,
        );
        assert_eq!(r.detect_secs, 10.0);
        assert!((r.total_secs() - (r.detect_secs + r.resume_secs + r.replay_secs)).abs() < 1e-12);
        // replay = steps-since-snapshot × full-width step time, exactly
        let step = m
            .step_time(algo, 1024, 32, RESNET50_GRAD_BYTES_FP16, RESNET50_BN_BYTES_FP32)
            .total_secs();
        assert!((r.replay_secs - 100.0 * step).abs() < 1e-9);
        // resume pays the control constant plus a full-state (4× fp16
        // grads) redistribution — strictly more than one gradient window
        let one_grad = m
            .collective_cost(algo, 1024, RESNET50_GRAD_BYTES_FP16)
            .total_secs();
        assert!(r.resume_secs > one_grad);
        // a snapshot at every boundary (zero gap) leaves only the outage
        // and the resume work — the durability subsystem's floor
        let r0 = m.restart_time(
            algo,
            1024,
            32,
            RESNET50_GRAD_BYTES_FP16,
            RESNET50_BN_BYTES_FP32,
            0,
            10.0,
        );
        assert_eq!(r0.replay_secs, 0.0);
        assert!(r0.total_secs() < r.total_secs());
        // halving the snapshot cadence halves the expected replay term
        let r_half = m.restart_time(
            algo,
            1024,
            32,
            RESNET50_GRAD_BYTES_FP16,
            RESNET50_BN_BYTES_FP32,
            50,
            10.0,
        );
        assert!((r.replay_secs - 2.0 * r_half.replay_secs).abs() < 1e-9);
    }

    /// Straggler pricing decomposes additively, tolerate scales linearly
    /// with the remainder, and the policy comparison flips the right way:
    /// demotion pays for a long remainder at a big slow factor, tolerating
    /// wins when the run is nearly over.
    #[test]
    fn straggler_time_decomposition_and_tradeoff() {
        let m = ClusterModel::abci_v100();
        let n = 1024usize;
        let algo = torus_at(n);
        let algo_after = torus_at(n - 1);
        let s = m.straggler_time(
            algo,
            algo_after,
            n,
            32,
            RESNET50_GRAD_BYTES_FP16,
            RESNET50_BN_BYTES_FP32,
            10_000,
            4.0,
            8,
            2.0,
        );
        assert!(
            (s.demote_secs() - (s.detect_secs + s.replan_secs + s.healthy_secs)).abs() < 1e-12
        );
        // tolerate = remaining × slow step, exactly: twice the remainder is
        // twice the tolerate bill
        let s2 = m.straggler_time(
            algo,
            algo_after,
            n,
            32,
            RESNET50_GRAD_BYTES_FP16,
            RESNET50_BN_BYTES_FP32,
            20_000,
            4.0,
            8,
            2.0,
        );
        assert!((s2.tolerate_secs - 2.0 * s.tolerate_secs).abs() < 1e-9);
        // a 4× straggler over 10k remaining steps: draining it pays
        assert!(s.demotion_pays(), "demote {} !< tolerate {}", s.demote_secs(), s.tolerate_secs);
        // ...but with almost nothing left to run, the re-plan is pure loss
        let tail = m.straggler_time(
            algo,
            algo_after,
            n,
            32,
            RESNET50_GRAD_BYTES_FP16,
            RESNET50_BN_BYTES_FP32,
            8,
            4.0,
            8,
            2.0,
        );
        assert!(!tail.demotion_pays());
        // detection never exceeds the remainder; with detect >= remaining
        // there is nothing left to run at healthy pace
        assert_eq!(tail.healthy_secs, 0.0);
        // a slow_factor at 1 (no straggler) makes tolerate the healthy
        // baseline: demote can only add re-plan overhead on fewer ranks
        let none = m.straggler_time(
            algo,
            algo_after,
            n,
            32,
            RESNET50_GRAD_BYTES_FP16,
            RESNET50_BN_BYTES_FP32,
            10_000,
            1.0,
            8,
            0.0,
        );
        assert!(!none.demotion_pays());
    }

    /// The heterogeneous step model: the slowest rank sets the synchronous
    /// pace, the tax is slowest − median, and a uniform cluster pays none.
    #[test]
    fn hetero_step_exposes_the_straggler_tax() {
        let m = ClusterModel::abci_v100();
        let n = 256usize;
        let algo = torus_at(n);
        let hetero = HeteroModel {
            seed: 42,
            compute_jitter: 0.05,
            link_jitter: 0.05,
            straggler_prob: 0.1,
            straggler_factor: 4.0,
        };
        let h = m.hetero_step_time(
            algo,
            n,
            32,
            RESNET50_GRAD_BYTES_FP16,
            RESNET50_BN_BYTES_FP32,
            &hetero,
        );
        let base = m
            .step_time(algo, n, 32, RESNET50_GRAD_BYTES_FP16, RESNET50_BN_BYTES_FP32)
            .total_secs();
        // the elected straggler dominates: the sync step carries roughly
        // its 4× compute, and the pace-setter is an elected rank
        assert!(h.slowest_secs > h.median_secs);
        assert!(hetero.is_straggler(h.slowest_rank));
        assert!((h.straggler_tax_secs - (h.slowest_secs - h.median_secs)).abs() < 1e-12);
        // jitter alone keeps the median within a few percent of nominal
        assert!(h.median_secs >= base && h.median_secs < base * 1.2);
        // a uniform cluster pays no tax and runs at the nominal step
        let u = m.hetero_step_time(
            algo,
            n,
            32,
            RESNET50_GRAD_BYTES_FP16,
            RESNET50_BN_BYTES_FP32,
            &HeteroModel::uniform(0),
        );
        assert!((u.straggler_tax_secs).abs() < 1e-12);
        assert!((u.slowest_secs - base).abs() < 1e-9);
    }

    #[test]
    fn hierarchical_phase_bytes_formula() {
        let m = ClusterModel::abci_v100();
        let c = m.collective_cost(Algo::Hierarchical { group: 4 }, 16, 1600.0);
        assert_eq!(c.phases.len(), 3);
        assert_eq!(c.phases[0].bytes_per_step, 400.0); // n/g
        assert_eq!(c.phases[1].bytes_per_step, 100.0); // n/g/groups
        assert_eq!(c.phases[1].steps, 6); // 2(groups-1)
    }
}
