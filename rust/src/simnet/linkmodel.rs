//! α-β link model of the ABCI interconnect (paper §3.1 hardware).
//!
//! Each peer-to-peer hop costs `α + bytes·β_eff`. Two link classes:
//!
//!  * **NVLink2** (intra-node, 4 V100s): low latency, ~40 GB/s effective
//!    per-direction p2p.
//!  * **InfiniBand EDR ×2** (inter-node): ~5 µs MPI-level latency,
//!    12.5 GB/s per flow (one EDR rail), 25 GB/s per node aggregate. When
//!    more concurrent flows leave a node than there are rails, they share
//!    aggregate bandwidth (`β` scales with the flow/rail ratio).
//!
//! Large fabrics add congestion: beyond `congestion_free_nodes` the
//! effective β grows linearly with node count (adaptive-routing/fat-tree
//! oversubscription pressure). The constants below are calibrated so the
//! model reproduces the *shape* of paper Tables 2 & 6 (who wins, by what
//! factor, where efficiency bends); EXPERIMENTS.md records model-vs-paper
//! per row.

use crate::cluster::LinkClass;
use crate::collectives::transport::chaos::unit;
use crate::collectives::transport::mix64;

/// Per-rank hardware heterogeneity: deterministic compute/link speed
/// multipliers, plus a seeded election of chronic stragglers.
///
/// Real clusters are never uniform — thermal throttling, a flaky DIMM, a
/// shared-rack neighbour, one oversubscribed leaf switch — and under
/// *synchronous* SGD the whole cluster converges to the slowest rank's
/// pace. This model prices that: every rank gets a jitter multiplier that
/// is a pure function of `(seed, rank)` (so the functional and analytic
/// paths agree on who is slow), and a `straggler_prob` fraction of ranks
/// is elected chronically slow by `straggler_factor`. The election uses
/// the **same key schedule as the chaos harness**
/// (`ChaosConfig::rank_slow_multiplier`), so a chaos run and its simnet
/// projection pick the same victims for the same seed.
#[derive(Debug, Clone)]
pub struct HeteroModel {
    pub seed: u64,
    /// Peak relative compute jitter across healthy ranks: each rank's
    /// compute multiplier is uniform in `[1, 1 + compute_jitter)`.
    pub compute_jitter: f64,
    /// Peak relative link jitter: link multiplier in `[1, 1 + link_jitter)`.
    pub link_jitter: f64,
    /// Fraction of ranks elected chronic stragglers.
    pub straggler_prob: f64,
    /// Extra compute multiplier an elected straggler carries.
    pub straggler_factor: f64,
}

/// Rank-election key salt — keep identical to the chaos harness's
/// `rank_slow_multiplier` so both paths elect the same slow ranks.
const SLOW_ELECTION_SALT: u64 = 0x5106_C0DE;

impl HeteroModel {
    /// A perfectly homogeneous cluster (all multipliers exactly 1).
    pub fn uniform(seed: u64) -> Self {
        Self {
            seed,
            compute_jitter: 0.0,
            link_jitter: 0.0,
            straggler_prob: 0.0,
            straggler_factor: 1.0,
        }
    }

    /// Whether `rank` is elected a chronic straggler under this seed.
    pub fn is_straggler(&self, rank: usize) -> bool {
        if self.straggler_prob <= 0.0 {
            return false;
        }
        let key = mix64(self.seed ^ mix64(rank as u64 ^ SLOW_ELECTION_SALT));
        unit(key) < self.straggler_prob
    }

    /// Compute-speed multiplier for `rank` (≥ 1; 1 = nominal V100 pace).
    pub fn compute_multiplier(&self, rank: usize) -> f64 {
        let key = mix64(self.seed ^ mix64(rank as u64 ^ 0xC0_FFEE));
        let base = 1.0 + self.compute_jitter.max(0.0) * unit(key);
        if self.is_straggler(rank) {
            base * self.straggler_factor.max(1.0)
        } else {
            base
        }
    }

    /// Link-time multiplier for `rank`'s hops (≥ 1; 1 = nominal fabric).
    pub fn link_multiplier(&self, rank: usize) -> f64 {
        let key = mix64(self.seed ^ mix64(rank as u64 ^ 0x11_4B));
        1.0 + self.link_jitter.max(0.0) * unit(key)
    }
}

/// α-β parameters for one cluster fabric.
#[derive(Debug, Clone)]
pub struct LinkModel {
    /// NVLink2 latency (s).
    pub alpha_intra: f64,
    /// NVLink2 seconds/byte.
    pub beta_intra: f64,
    /// InfiniBand latency (s).
    pub alpha_inter: f64,
    /// Seconds/byte of ONE inter-node flow using one rail.
    pub beta_inter_flow: f64,
    /// Node aggregate inter bandwidth in bytes/s (all rails).
    pub node_inter_bw: f64,
    /// IB rails per node (2 on ABCI).
    pub rails_per_node: usize,
    /// Node count up to which the fabric behaves full-bisection.
    pub congestion_free_nodes: usize,
    /// Relative β growth per `congestion_free_nodes` beyond the free zone.
    pub congestion_slope: f64,
}

impl LinkModel {
    /// ABCI defaults (V100 nodes, NVLink2, 2× IB-EDR) — see module docs.
    pub fn abci() -> Self {
        Self {
            alpha_intra: 2.0e-6,
            beta_intra: 1.0 / 40.0e9,
            alpha_inter: 5.0e-6,
            beta_inter_flow: 1.0 / 12.5e9,
            node_inter_bw: 25.0e9,
            rails_per_node: 2,
            congestion_free_nodes: 512,
            congestion_slope: 1.0,
        }
    }

    /// Congestion multiplier for a job spanning `nodes` nodes.
    pub fn congestion(&self, nodes: usize) -> f64 {
        if nodes <= self.congestion_free_nodes {
            1.0
        } else {
            1.0 + self.congestion_slope * (nodes - self.congestion_free_nodes) as f64
                / self.congestion_free_nodes as f64
        }
    }

    /// Effective seconds/byte for one flow of `concurrent_flows` leaving a
    /// node simultaneously, on a fabric of `nodes` nodes.
    pub fn beta_inter(&self, concurrent_flows: usize, nodes: usize) -> f64 {
        let per_flow_share = self.node_inter_bw / concurrent_flows.max(1) as f64;
        let single_rail = 1.0 / self.beta_inter_flow;
        let bw = per_flow_share.min(single_rail);
        self.congestion(nodes) / bw
    }

    /// Time of one p2p hop of `bytes` over `class`, with `concurrent_flows`
    /// inter-node flows per node and `nodes` total nodes.
    pub fn hop_time(
        &self,
        class: LinkClass,
        bytes: f64,
        concurrent_flows: usize,
        nodes: usize,
    ) -> f64 {
        match class {
            LinkClass::Local => 0.0,
            LinkClass::IntraNode => self.alpha_intra + bytes * self.beta_intra,
            LinkClass::InterNode => {
                self.alpha_inter + bytes * self.beta_inter(concurrent_flows, nodes)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hetero_multipliers_are_deterministic_and_bounded() {
        let h = HeteroModel {
            seed: 7,
            compute_jitter: 0.05,
            link_jitter: 0.10,
            straggler_prob: 0.25,
            straggler_factor: 3.0,
        };
        let n = 64usize;
        let comp: Vec<f64> = (0..n).map(|r| h.compute_multiplier(r)).collect();
        let link: Vec<f64> = (0..n).map(|r| h.link_multiplier(r)).collect();
        // pure functions of (seed, rank)
        assert_eq!(comp, (0..n).map(|r| h.compute_multiplier(r)).collect::<Vec<_>>());
        assert_eq!(link, (0..n).map(|r| h.link_multiplier(r)).collect::<Vec<_>>());
        // healthy ranks jitter inside [1, 1+jitter); stragglers carry the
        // factor on top of their jitter
        for r in 0..n {
            if h.is_straggler(r) {
                assert!(comp[r] >= 3.0 && comp[r] < 3.0 * 1.05, "rank {r}: {}", comp[r]);
            } else {
                assert!(comp[r] >= 1.0 && comp[r] < 1.05, "rank {r}: {}", comp[r]);
            }
            assert!(link[r] >= 1.0 && link[r] < 1.10);
        }
        // ~25% of ranks elected; not none, not all
        let slow = (0..n).filter(|&r| h.is_straggler(r)).count();
        assert!(slow > 0 && slow < n / 2, "{slow} stragglers of {n}");
        // a different seed elects a different set
        let other = HeteroModel { seed: 8, ..h.clone() };
        assert_ne!(
            (0..n).map(|r| h.is_straggler(r)).collect::<Vec<_>>(),
            (0..n).map(|r| other.is_straggler(r)).collect::<Vec<_>>(),
        );
        // the uniform cluster is exactly multiplier-free
        let u = HeteroModel::uniform(7);
        for r in 0..n {
            assert_eq!(u.compute_multiplier(r), 1.0);
            assert_eq!(u.link_multiplier(r), 1.0);
            assert!(!u.is_straggler(r));
        }
    }

    /// The simnet election and the chaos harness's must agree rank-by-rank
    /// for the same seed — a chaos run and its analytic projection pick the
    /// same victims.
    #[test]
    fn hetero_election_matches_chaos_harness() {
        let h = HeteroModel {
            seed: 42,
            compute_jitter: 0.0,
            link_jitter: 0.0,
            straggler_prob: 0.25,
            straggler_factor: 4.0,
        };
        let chaos = crate::collectives::ChaosConfig {
            enabled: true,
            slow_prob: 0.25,
            slow_factor: 4.0,
            seed: 42,
            ..Default::default()
        };
        for r in 0..64 {
            assert_eq!(
                h.is_straggler(r),
                chaos.rank_slow_multiplier(r) > 1.0,
                "rank {r} election diverged between simnet and chaos"
            );
        }
    }

    #[test]
    fn congestion_kicks_in_past_free_zone() {
        let m = LinkModel::abci();
        assert_eq!(m.congestion(256), 1.0);
        assert_eq!(m.congestion(512), 1.0);
        assert!((m.congestion(768) - 1.5).abs() < 1e-12);
        assert!((m.congestion(1024) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn flow_sharing_caps_at_single_rail() {
        let m = LinkModel::abci();
        // one flow: capped by single-rail 12.5 GB/s, not node 25 GB/s
        assert!((m.beta_inter(1, 1) - 1.0 / 12.5e9).abs() < 1e-15);
        // two flows: each gets a full rail
        assert!((m.beta_inter(2, 1) - 1.0 / 12.5e9).abs() < 1e-15);
        // four flows: share 25 GB/s -> 6.25 each
        assert!((m.beta_inter(4, 1) - 1.0 / 6.25e9).abs() < 1e-15);
    }

    #[test]
    fn hop_times_ordered_by_class() {
        let m = LinkModel::abci();
        let b = 1.0e6;
        let local = m.hop_time(LinkClass::Local, b, 1, 1);
        let intra = m.hop_time(LinkClass::IntraNode, b, 1, 1);
        let inter = m.hop_time(LinkClass::InterNode, b, 1, 1);
        assert_eq!(local, 0.0);
        assert!(intra < inter);
        // 1 MB over NVLink ~ 27 µs; over one EDR rail ~ 85 µs
        assert!((intra - (2.0e-6 + 1.0e6 / 40.0e9)).abs() < 1e-12);
    }
}
