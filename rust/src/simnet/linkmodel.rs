//! α-β link model of the ABCI interconnect (paper §3.1 hardware).
//!
//! Each peer-to-peer hop costs `α + bytes·β_eff`. Two link classes:
//!
//!  * **NVLink2** (intra-node, 4 V100s): low latency, ~40 GB/s effective
//!    per-direction p2p.
//!  * **InfiniBand EDR ×2** (inter-node): ~5 µs MPI-level latency,
//!    12.5 GB/s per flow (one EDR rail), 25 GB/s per node aggregate. When
//!    more concurrent flows leave a node than there are rails, they share
//!    aggregate bandwidth (`β` scales with the flow/rail ratio).
//!
//! Large fabrics add congestion: beyond `congestion_free_nodes` the
//! effective β grows linearly with node count (adaptive-routing/fat-tree
//! oversubscription pressure). The constants below are calibrated so the
//! model reproduces the *shape* of paper Tables 2 & 6 (who wins, by what
//! factor, where efficiency bends); EXPERIMENTS.md records model-vs-paper
//! per row.

use crate::cluster::LinkClass;

/// α-β parameters for one cluster fabric.
#[derive(Debug, Clone)]
pub struct LinkModel {
    /// NVLink2 latency (s).
    pub alpha_intra: f64,
    /// NVLink2 seconds/byte.
    pub beta_intra: f64,
    /// InfiniBand latency (s).
    pub alpha_inter: f64,
    /// Seconds/byte of ONE inter-node flow using one rail.
    pub beta_inter_flow: f64,
    /// Node aggregate inter bandwidth in bytes/s (all rails).
    pub node_inter_bw: f64,
    /// IB rails per node (2 on ABCI).
    pub rails_per_node: usize,
    /// Node count up to which the fabric behaves full-bisection.
    pub congestion_free_nodes: usize,
    /// Relative β growth per `congestion_free_nodes` beyond the free zone.
    pub congestion_slope: f64,
}

impl LinkModel {
    /// ABCI defaults (V100 nodes, NVLink2, 2× IB-EDR) — see module docs.
    pub fn abci() -> Self {
        Self {
            alpha_intra: 2.0e-6,
            beta_intra: 1.0 / 40.0e9,
            alpha_inter: 5.0e-6,
            beta_inter_flow: 1.0 / 12.5e9,
            node_inter_bw: 25.0e9,
            rails_per_node: 2,
            congestion_free_nodes: 512,
            congestion_slope: 1.0,
        }
    }

    /// Congestion multiplier for a job spanning `nodes` nodes.
    pub fn congestion(&self, nodes: usize) -> f64 {
        if nodes <= self.congestion_free_nodes {
            1.0
        } else {
            1.0 + self.congestion_slope * (nodes - self.congestion_free_nodes) as f64
                / self.congestion_free_nodes as f64
        }
    }

    /// Effective seconds/byte for one flow of `concurrent_flows` leaving a
    /// node simultaneously, on a fabric of `nodes` nodes.
    pub fn beta_inter(&self, concurrent_flows: usize, nodes: usize) -> f64 {
        let per_flow_share = self.node_inter_bw / concurrent_flows.max(1) as f64;
        let single_rail = 1.0 / self.beta_inter_flow;
        let bw = per_flow_share.min(single_rail);
        self.congestion(nodes) / bw
    }

    /// Time of one p2p hop of `bytes` over `class`, with `concurrent_flows`
    /// inter-node flows per node and `nodes` total nodes.
    pub fn hop_time(
        &self,
        class: LinkClass,
        bytes: f64,
        concurrent_flows: usize,
        nodes: usize,
    ) -> f64 {
        match class {
            LinkClass::Local => 0.0,
            LinkClass::IntraNode => self.alpha_intra + bytes * self.beta_intra,
            LinkClass::InterNode => {
                self.alpha_inter + bytes * self.beta_inter(concurrent_flows, nodes)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn congestion_kicks_in_past_free_zone() {
        let m = LinkModel::abci();
        assert_eq!(m.congestion(256), 1.0);
        assert_eq!(m.congestion(512), 1.0);
        assert!((m.congestion(768) - 1.5).abs() < 1e-12);
        assert!((m.congestion(1024) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn flow_sharing_caps_at_single_rail() {
        let m = LinkModel::abci();
        // one flow: capped by single-rail 12.5 GB/s, not node 25 GB/s
        assert!((m.beta_inter(1, 1) - 1.0 / 12.5e9).abs() < 1e-15);
        // two flows: each gets a full rail
        assert!((m.beta_inter(2, 1) - 1.0 / 12.5e9).abs() < 1e-15);
        // four flows: share 25 GB/s -> 6.25 each
        assert!((m.beta_inter(4, 1) - 1.0 / 6.25e9).abs() < 1e-15);
    }

    #[test]
    fn hop_times_ordered_by_class() {
        let m = LinkModel::abci();
        let b = 1.0e6;
        let local = m.hop_time(LinkClass::Local, b, 1, 1);
        let intra = m.hop_time(LinkClass::IntraNode, b, 1, 1);
        let inter = m.hop_time(LinkClass::InterNode, b, 1, 1);
        assert_eq!(local, 0.0);
        assert!(intra < inter);
        // 1 MB over NVLink ~ 27 µs; over one EDR rail ~ 85 µs
        assert!((intra - (2.0e-6 + 1.0e6 / 40.0e9)).abs() < 1e-12);
    }
}
