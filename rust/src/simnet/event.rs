//! Discrete-event validation of the analytical cost model.
//!
//! Replays the *actual* ring schedules hop by hop over per-rank clocks:
//! a rank finishes step `s` of a ring when both it and its upstream
//! neighbour finished step `s-1`, plus the hop's link time (classified per
//! edge by the packed placement, not by the phase-level worst case). This
//! captures straggler propagation around heterogeneous rings — the effect
//! the closed form approximates with its worst-link assumption — and the
//! two are asserted to agree within tolerance in tests and in the Table 6
//! bench. [`simulate_collective_events`] additionally counts each rank's
//! peer-to-peer hops, which must match `Collective::p2p_steps` exactly
//! (the functional layer and the simulator describe the same schedules).

use crate::cluster::{Grid, Placement};

use super::cost::{Algo, ClusterModel};

/// Per-rank clock simulation of one ring phase over disjoint `rings`.
///
/// Every ring advances `steps` times; each hop's cost is the edge's real
/// link class. `flows` is the concurrent inter-node flow count used for
/// bandwidth sharing (phase-level, as in the analytic model). `hops`
/// accumulates each participating rank's p2p step count.
#[allow(clippy::too_many_arguments)]
fn simulate_phase(
    clocks: &mut [f64],
    hops: &mut [usize],
    rings: &[Vec<usize>],
    steps: usize,
    bytes_per_step: f64,
    flows: usize,
    model: &ClusterModel,
    placement: &Placement,
) {
    let nodes = placement.nodes();
    for _ in 0..steps {
        // Each ring hop: rank receives from its left neighbour.
        let prev: Vec<f64> = clocks.to_vec();
        for ring in rings {
            let k = ring.len();
            if k <= 1 {
                continue;
            }
            for (pos, &rank) in ring.iter().enumerate() {
                let left = ring[(pos + k - 1) % k];
                let class = placement.classify(left, rank);
                let t_hop = model.lm.hop_time(class, bytes_per_step, flows, nodes);
                let ready = prev[rank].max(prev[left]);
                clocks[rank] = clocks[rank].max(ready + t_hop);
                hops[rank] += 1;
            }
        }
    }
}

/// Event-driven time of one sum-all-reduce of `bytes` under `algo`.
pub fn simulate_collective(model: &ClusterModel, algo: Algo, n_ranks: usize, bytes: f64) -> f64 {
    simulate_collective_events(model, algo, n_ranks, bytes).0
}

/// Event-driven `(finish time, per-rank p2p steps)` of one sum-all-reduce.
///
/// The step count is the maximum hops any rank executed; for the uniform
/// schedules simulated here every participating rank does the same number,
/// and it must equal the matching `Collective::p2p_steps`.
pub fn simulate_collective_events(
    model: &ClusterModel,
    algo: Algo,
    n_ranks: usize,
    bytes: f64,
) -> (f64, usize) {
    let mut clocks = vec![0.0f64; n_ranks];
    let mut hops = vec![0usize; n_ranks];
    match algo {
        Algo::Ring => {
            let grid = Grid::new(n_ranks, 1);
            let placement = Placement::packed(grid, model.gpus_per_node);
            let ring: Vec<Vec<usize>> = vec![(0..n_ranks).collect()];
            simulate_phase(
                &mut clocks,
                &mut hops,
                &ring,
                2 * (n_ranks - 1),
                bytes / n_ranks as f64,
                1,
                model,
                &placement,
            );
        }
        Algo::Hierarchical { group } => {
            assert_eq!(n_ranks % group, 0);
            let groups = n_ranks / group;
            let grid = Grid::new(n_ranks, 1);
            let placement = Placement::packed(grid, model.gpus_per_node);
            let intra: Vec<Vec<usize>> = (0..groups)
                .map(|g| (0..group).map(|i| g * group + i).collect())
                .collect();
            let inter: Vec<Vec<usize>> = (0..group)
                .map(|pos| (0..groups).map(|g| g * group + pos).collect())
                .collect();
            simulate_phase(
                &mut clocks,
                &mut hops,
                &intra,
                group - 1,
                bytes / group as f64,
                1,
                model,
                &placement,
            );
            simulate_phase(
                &mut clocks,
                &mut hops,
                &inter,
                2 * (groups - 1),
                bytes / (group * groups) as f64,
                group,
                model,
                &placement,
            );
            simulate_phase(
                &mut clocks,
                &mut hops,
                &intra,
                group - 1,
                bytes / group as f64,
                1,
                model,
                &placement,
            );
        }
        Algo::HalvingDoubling => {
            assert!(n_ranks.is_power_of_two());
            let grid = Grid::new(n_ranks, 1);
            let placement = Placement::packed(grid, model.gpus_per_node);
            let nodes = placement.nodes();
            let rounds = n_ranks.trailing_zeros() as usize;
            // scatter rounds r = 0..rounds (stride 2^r), then gather back.
            let order: Vec<usize> = (0..rounds).chain((0..rounds).rev()).collect();
            for &r in &order {
                // round at stride 2^r moves bytes/2^{r+1} in each direction
                let b = bytes / 2f64.powi(r as i32 + 1);
                let prev = clocks.clone();
                for me in 0..n_ranks {
                    let partner = me ^ (1 << r);
                    let class = placement.classify(me, partner);
                    let t = model.lm.hop_time(class, b, model.gpus_per_node, nodes);
                    clocks[me] = prev[me].max(prev[partner]) + t;
                    hops[me] += 1;
                }
            }
        }
        Algo::Torus { x, y } => {
            assert_eq!(x * y, n_ranks);
            let grid = Grid::new(x, y);
            let placement = Placement::packed(grid, model.gpus_per_node);
            let rows: Vec<Vec<usize>> = (0..y)
                .map(|r| (0..x).map(|c| grid.rank(c, r)).collect())
                .collect();
            let cols: Vec<Vec<usize>> = (0..x)
                .map(|c| (0..y).map(|r| grid.rank(c, r)).collect())
                .collect();
            let v_flows = model.gpus_per_node.min(x);
            simulate_phase(
                &mut clocks,
                &mut hops,
                &rows,
                x.saturating_sub(1),
                bytes / x as f64,
                1,
                model,
                &placement,
            );
            simulate_phase(
                &mut clocks,
                &mut hops,
                &cols,
                2 * y.saturating_sub(1),
                bytes / (x * y) as f64,
                v_flows,
                model,
                &placement,
            );
            simulate_phase(
                &mut clocks,
                &mut hops,
                &rows,
                x.saturating_sub(1),
                bytes / x as f64,
                1,
                model,
                &placement,
            );
        }
    }
    let finish = clocks.iter().cloned().fold(0.0, f64::max);
    let steps = hops.iter().copied().max().unwrap_or(0);
    (finish, steps)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::{
        Collective, HalvingDoubling, HierarchicalAllReduce, RingAllReduce, TorusAllReduce,
    };
    use crate::simnet::compute::RESNET50_GRAD_BYTES_FP16;
    use crate::util::quickcheck::prop_seeded;

    #[test]
    fn event_sim_close_to_analytic_torus() {
        let m = ClusterModel::abci_v100();
        let bytes = RESNET50_GRAD_BYTES_FP16;
        for (x, y) in [(2usize, 2usize), (8, 8), (32, 32), (64, 32)] {
            let n = x * y;
            let analytic = m.collective_cost(Algo::Torus { x, y }, n, bytes).total_secs();
            let event = simulate_collective(&m, Algo::Torus { x, y }, n, bytes);
            let rel = (event - analytic).abs() / analytic;
            // event sim sees mixed intra/inter hops the closed form rounds
            // up to worst-case; agreement within 25% validates the shape.
            assert!(
                rel < 0.25,
                "torus {x}x{y}: analytic {analytic:.6} vs event {event:.6} (rel {rel:.3})"
            );
            // worst-link closed form should be an upper-ish bound
            assert!(event <= analytic * 1.05);
        }
    }

    #[test]
    fn event_sim_close_to_analytic_ring() {
        let m = ClusterModel::abci_v100();
        let bytes = RESNET50_GRAD_BYTES_FP16;
        for n in [8usize, 64, 256] {
            let analytic = m.collective_cost(Algo::Ring, n, bytes).total_secs();
            let event = simulate_collective(&m, Algo::Ring, n, bytes);
            let rel = (event - analytic).abs() / analytic;
            assert!(rel < 0.25, "ring n={n}: {analytic:.6} vs {event:.6}");
        }
    }

    #[test]
    fn event_sim_hierarchical_runs() {
        let m = ClusterModel::abci_v100();
        let t = simulate_collective(
            &m,
            Algo::Hierarchical { group: 4 },
            64,
            RESNET50_GRAD_BYTES_FP16,
        );
        assert!(t > 0.0 && t.is_finite());
    }

    #[test]
    fn straggler_propagates_in_heterogeneous_ring() {
        // A ring spanning two nodes is gated by its slowest (IB) hops even
        // though most hops are NVLink: event time >> pure-NVLink estimate.
        let m = ClusterModel::abci_v100();
        let bytes = 8.0e6;
        let t = simulate_collective(&m, Algo::Ring, 8, bytes);
        let pure_nvlink = 14.0 * m.lm.hop_time(crate::cluster::LinkClass::IntraNode, bytes / 8.0, 1, 2);
        assert!(t > pure_nvlink, "{t} vs {pure_nvlink}");
    }

    /// Property: for seeded random grids and payloads, the closed-form
    /// `CollectiveCost::total_secs` matches the discrete-event replay
    /// within tolerance, and the functional layer's `Collective::p2p_steps`
    /// matches the simulator's per-rank event count exactly.
    #[test]
    fn property_cost_matches_event_and_step_counts() {
        let m = ClusterModel::abci_v100();
        // Square-ish torus shapes (the family the paper and the closed
        // form target — Table 4 grids are all of this kind).
        let torus_grids: &[(usize, usize)] = &[
            (2, 2),
            (2, 4),
            (4, 2),
            (4, 4),
            (4, 8),
            (8, 8),
            (8, 16),
            (16, 16),
            (32, 32),
            (64, 32),
        ];
        prop_seeded(0xC057_0E0E, 24, |g| {
            let bytes = f64::from(g.f32_in(0.5..50.0)) * 1.0e6;

            // 2D-torus: time within tolerance, steps exact.
            let &(x, y) = g.choose(torus_grids);
            let n = x * y;
            let algo = Algo::Torus { x, y };
            let analytic = m.collective_cost(algo, n, bytes).total_secs();
            let (event, steps) = simulate_collective_events(&m, algo, n, bytes);
            let rel = (event - analytic).abs() / analytic;
            assert!(
                rel < 0.25 && event <= analytic * 1.05,
                "torus {x}x{y} @ {bytes:.0}B: analytic {analytic:.6} vs event {event:.6}"
            );
            assert_eq!(
                steps,
                TorusAllReduce::new(x, y).p2p_steps(n),
                "torus {x}x{y} step count"
            );

            // Flat ring.
            let rn = *g.choose(&[8usize, 16, 64, 128, 256]);
            let analytic = m.collective_cost(Algo::Ring, rn, bytes).total_secs();
            let (event, steps) = simulate_collective_events(&m, Algo::Ring, rn, bytes);
            let rel = (event - analytic).abs() / analytic;
            assert!(
                rel < 0.25 && event <= analytic * 1.05,
                "ring n={rn}: analytic {analytic:.6} vs event {event:.6}"
            );
            assert_eq!(steps, RingAllReduce.p2p_steps(rn), "ring {rn} step count");

            // Hierarchical with node-sized groups (g=4 matches ABCI).
            let groups = *g.choose(&[4usize, 8, 16]);
            let hn = 4 * groups;
            let algo = Algo::Hierarchical { group: 4 };
            let analytic = m.collective_cost(algo, hn, bytes).total_secs();
            let (event, steps) = simulate_collective_events(&m, algo, hn, bytes);
            let rel = (event - analytic).abs() / analytic;
            assert!(
                rel < 0.25 && event <= analytic * 1.05,
                "hierarchical n={hn}: analytic {analytic:.6} vs event {event:.6}"
            );
            assert_eq!(
                steps,
                HierarchicalAllReduce::new(4).p2p_steps(hn),
                "hierarchical {hn} step count"
            );

            // Halving-doubling: the analytic form prices every round at the
            // inter-node class while early rounds are physically intra-node,
            // so only the step count is exact (and the event time bounded).
            let hd_n = *g.choose(&[8usize, 16, 64, 256]);
            let algo = Algo::HalvingDoubling;
            let analytic = m.collective_cost(algo, hd_n, bytes).total_secs();
            let (event, steps) = simulate_collective_events(&m, algo, hd_n, bytes);
            assert!(event > 0.0 && event <= analytic * 1.05, "hd n={hd_n}");
            assert_eq!(steps, HalvingDoubling.p2p_steps(hd_n), "hd {hd_n} step count");
        });
    }
}
