//! ABCI-scale network/compute simulator (DESIGN.md §4 substitution).
//!
//! The paper's measurements come from up to 4096 V100s; this testbed has
//! CPU threads. The *functional* collectives in `collectives::` prove
//! numerics at thread scale; `simnet` projects step time, throughput and
//! GPU scaling efficiency to cluster scale with:
//!
//! * [`linkmodel`] — α-β link model (NVLink2 / 2×IB-EDR, flow sharing,
//!   fabric congestion),
//! * [`compute`]  — V100 ResNet-50 compute-time model calibrated to the
//!   paper's own single-node row of Table 6,
//! * [`cost`]     — closed-form per-phase collective pricing → Tables 2 & 6,
//! * [`event`]    — hop-by-hop discrete-event replay validating the closed
//!   form.
//!
//! Clusters are also modelled as *heterogeneous*: [`HeteroModel`] gives
//! every rank deterministic compute/link multipliers (seeded jitter plus a
//! chronic-straggler election that matches the chaos harness key-for-key),
//! [`ClusterModel::hetero_step_time`] exposes the per-step straggler tax
//! synchrony levies, and [`ClusterModel::straggler_time`] prices the
//! tolerate-vs-demote policy choice behind `[fault.straggler]`.

pub mod compute;
pub mod cost;
pub mod event;
pub mod linkmodel;

pub use compute::{ComputeModel, RESNET50_BN_BYTES_FP32, RESNET50_GRAD_BYTES_FP16};
pub use cost::{
    Algo, ClusterModel, CollectiveCost, HeteroStep, OverlappedStep, RecoveryCost, RejoinCost,
    RestartCost, StepBreakdown, StragglerCost,
};
pub use event::{simulate_collective, simulate_collective_events};
pub use linkmodel::{HeteroModel, LinkModel};
