//! V100 compute-time model for ResNet-50 (paper workload).
//!
//! Calibrated against the paper's own single-node measurement: Table 6 row
//! one reports 2565 images/s on 4 GPUs with per-worker batch 32 — i.e.
//! ≈641 img/s per V100 including the (tiny) intra-node all-reduce. With the
//! NVLink cost model charging ~1.9 ms of communication per 49.9 ms step,
//! per-GPU pure-compute throughput comes out at ≈667 img/s, which is what
//! `ComputeModel::v100_resnet50` encodes via FLOP counts and an effective
//! utilisation factor.
//!
//! Batch-size dependence uses a saturation curve: small per-worker batches
//! underutilise the GPU (`b_half` is the batch at which half the peak is
//! reached); this matters for the paper's 16/worker phases (Table 3).

/// FLOPs for one ResNet-50 forward pass at 224×224 (fwd only).
pub const RESNET50_FWD_FLOPS: f64 = 3.9e9;

/// fwd+bwd multiplier (backward ≈ 2× forward).
pub const FWD_BWD_FACTOR: f64 = 3.0;

/// Gradient bytes exchanged per step: 25.5M params in FP16 (paper §3.2).
pub const RESNET50_GRAD_BYTES_FP16: f64 = 25.5e6 * 2.0;

/// BN-stat bytes exchanged per step in FP32: 53 BN layers, 2 vectors each
/// (mean, sqmean); total channel count ≈ 26.5K floats ≈ 0.2 MB. Small but
/// modelled, since the paper calls out its FP32 precision explicitly.
pub const RESNET50_BN_BYTES_FP32: f64 = 26_560.0 * 2.0 * 4.0;

/// Per-GPU compute-time model.
#[derive(Debug, Clone)]
pub struct ComputeModel {
    /// Peak sustainable images/sec at large batch (per GPU).
    pub peak_images_per_sec: f64,
    /// Batch at which throughput reaches half of peak.
    pub b_half: f64,
}

impl ComputeModel {
    /// V100 + mixed precision + NNL, calibrated to paper Table 6 (see
    /// module docs).
    pub fn v100_resnet50() -> Self {
        Self {
            peak_images_per_sec: 750.0,
            b_half: 4.0,
        }
    }

    /// Sustained images/sec at per-worker batch `b`.
    pub fn images_per_sec(&self, b: usize) -> f64 {
        let b = b as f64;
        self.peak_images_per_sec * b / (b + self.b_half)
    }

    /// Seconds of fwd+bwd compute for one step at per-worker batch `b`.
    pub fn step_seconds(&self, b: usize) -> f64 {
        b as f64 / self.images_per_sec(b)
    }

    /// Implied utilisation of the V100's 125 TFLOPS tensor-core peak.
    pub fn mxu_utilisation(&self, b: usize) -> f64 {
        let flops_per_sec = self.images_per_sec(b) * RESNET50_FWD_FLOPS * FWD_BWD_FACTOR;
        flops_per_sec / 125.0e12
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibration_matches_paper_single_gpu() {
        let m = ComputeModel::v100_resnet50();
        let thr = m.images_per_sec(32);
        // ≈667 img/s pure compute (module docs derivation)
        assert!((thr - 667.0).abs() < 10.0, "thr={thr}");
        assert!((m.step_seconds(32) - 0.048).abs() < 0.001);
    }

    #[test]
    fn small_batches_less_efficient() {
        let m = ComputeModel::v100_resnet50();
        assert!(m.images_per_sec(16) < m.images_per_sec(32));
        assert!(m.images_per_sec(16) > 0.5 * m.images_per_sec(32));
        // step time grows sublinearly with batch
        assert!(m.step_seconds(32) < 2.0 * m.step_seconds(16));
    }

    #[test]
    fn utilisation_is_plausible() {
        let m = ComputeModel::v100_resnet50();
        let u = m.mxu_utilisation(32);
        // mixed-precision ResNet-50 lands ~5-15% of the 125 TF peak
        assert!(u > 0.03 && u < 0.2, "utilisation {u}");
    }
}
