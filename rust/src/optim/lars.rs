//! Rust reference LARS optimizer (You, Gitman, Ginsburg, arXiv:1708.03888).
//!
//! Mirrors `python/compile/kernels/ref.py::lars_update` operation for
//! operation in FP32 — the cross-language correctness anchor: the
//! integration tests drive the AOT `apply_step` artifact (the Pallas LARS
//! kernel) and this implementation with identical inputs and require
//! agreement to ~1e-5. Also used directly by simulator-side training where
//! no PJRT artifact is loaded.

/// LARS hyper-parameters (paper §3.2 defaults).
#[derive(Debug, Clone, Copy)]
pub struct LarsConfig {
    /// Trust coefficient η (paper: 0.01).
    pub coeff: f32,
    /// Numerical epsilon in the trust-ratio denominator (paper: 1e-6).
    pub eps: f32,
    /// L2 weight decay folded into the update (not the loss).
    pub weight_decay: f32,
}

impl Default for LarsConfig {
    fn default() -> Self {
        Self {
            coeff: 0.01,
            eps: 1e-6,
            weight_decay: 5e-5,
        }
    }
}

/// Layer-wise trust ratio: `coeff·‖w‖ / (‖g‖ + wd·‖w‖ + eps)`, falling back
/// to 1.0 when either norm is zero (zero-init params / dead grads).
pub fn trust_ratio(w: &[f32], g: &[f32], cfg: &LarsConfig) -> f32 {
    let w_norm = l2_norm(w);
    let g_norm = l2_norm(g);
    if w_norm > 0.0 && g_norm > 0.0 {
        cfg.coeff * w_norm / (g_norm + cfg.weight_decay * w_norm + cfg.eps)
    } else {
        1.0
    }
}

fn l2_norm(xs: &[f32]) -> f32 {
    xs.iter().map(|&x| x * x).sum::<f32>().sqrt()
}

/// One in-place LARS step for a single tensor:
/// `m ← momentum·m + lr·trust·(g + wd·w)`; `w ← w − m`.
pub fn lars_step(
    w: &mut [f32],
    g: &[f32],
    m: &mut [f32],
    lr: f32,
    momentum: f32,
    cfg: &LarsConfig,
) {
    assert_eq!(w.len(), g.len());
    assert_eq!(w.len(), m.len());
    let scale = lr * trust_ratio(w, g, cfg);
    for ((wi, &gi), mi) in w.iter_mut().zip(g).zip(m.iter_mut()) {
        let upd = scale * (gi + cfg.weight_decay * *wi);
        *mi = momentum * *mi + upd;
        *wi -= *mi;
    }
}

/// LARS over a list of tensors (layer-wise trust ratios, like the paper).
pub fn lars_step_all(
    weights: &mut [Vec<f32>],
    grads: &[Vec<f32>],
    momenta: &mut [Vec<f32>],
    lr: f32,
    momentum: f32,
    cfg: &LarsConfig,
) {
    assert_eq!(weights.len(), grads.len());
    assert_eq!(weights.len(), momenta.len());
    for ((w, g), m) in weights.iter_mut().zip(grads).zip(momenta.iter_mut()) {
        lars_step(w, g, m, lr, momentum, cfg);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::prop;

    #[test]
    fn trust_ratio_formula() {
        let w = vec![3.0, 4.0]; // ‖w‖ = 5
        let g = vec![0.0, 2.0]; // ‖g‖ = 2
        let cfg = LarsConfig {
            coeff: 0.01,
            eps: 1e-6,
            weight_decay: 0.1,
        };
        let t = trust_ratio(&w, &g, &cfg);
        let want = 0.01 * 5.0 / (2.0 + 0.1 * 5.0 + 1e-6);
        assert!((t - want).abs() < 1e-7);
    }

    #[test]
    fn zero_norm_falls_back_to_unit_trust() {
        let cfg = LarsConfig::default();
        assert_eq!(trust_ratio(&[0.0; 4], &[1.0; 4], &cfg), 1.0);
        assert_eq!(trust_ratio(&[1.0; 4], &[0.0; 4], &cfg), 1.0);
    }

    #[test]
    fn step_matches_hand_computation() {
        let cfg = LarsConfig {
            coeff: 0.01,
            eps: 0.0,
            weight_decay: 0.0,
        };
        let mut w = vec![1.0f32, 0.0];
        let g = vec![1.0f32, 0.0];
        let mut m = vec![0.0f32, 0.0];
        // trust = 0.01·1/1 = 0.01; update = 0.5·0.01·g
        lars_step(&mut w, &g, &mut m, 0.5, 0.9, &cfg);
        assert!((w[0] - (1.0 - 0.005)).abs() < 1e-7);
        assert_eq!(w[1], 0.0);
        assert!((m[0] - 0.005).abs() < 1e-7);
        // second step accumulates momentum
        lars_step(&mut w, &g, &mut m, 0.5, 0.9, &cfg);
        assert!(m[0] > 0.005);
    }

    #[test]
    fn momentum_accelerates_constant_gradient() {
        let cfg = LarsConfig {
            weight_decay: 0.0,
            ..Default::default()
        };
        let g = vec![0.1f32; 8];
        let mut w_mom = vec![1.0f32; 8];
        let mut m_mom = vec![0.0f32; 8];
        let mut w_plain = vec![1.0f32; 8];
        let mut m_plain = vec![0.0f32; 8];
        for _ in 0..10 {
            lars_step(&mut w_mom, &g, &mut m_mom, 0.1, 0.9, &cfg);
            lars_step(&mut w_plain, &g, &mut m_plain, 0.1, 0.0, &cfg);
        }
        assert!(w_mom[0] < w_plain[0], "momentum must move further");
    }

    #[test]
    fn property_update_is_finite_and_descending_for_descent_direction() {
        prop(|gen| {
            let n = gen.usize_in(1..=64);
            let mut w: Vec<f32> = gen.vec_normal(n);
            let g: Vec<f32> = w.iter().map(|x| x * 0.1).collect(); // grad ∝ w
            let mut m = vec![0.0f32; n];
            let cfg = LarsConfig::default();
            let norm_before = l2_norm(&w);
            lars_step(&mut w, &g, &mut m, 0.5, 0.0, &cfg);
            assert!(w.iter().all(|x| x.is_finite()));
            if norm_before > 1e-3 {
                assert!(l2_norm(&w) <= norm_before, "step along -w must shrink ‖w‖");
            }
        });
    }
}
