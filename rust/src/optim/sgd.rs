//! Momentum-SGD baseline optimizer (the non-LARS comparison point used by
//! the ablation benches; Goyal et al. [1] style with L2 folded in).

/// One in-place momentum-SGD step for a single tensor:
/// `m ← momentum·m + lr·(g + wd·w)`; `w ← w − m`.
pub fn sgd_step(
    w: &mut [f32],
    g: &[f32],
    m: &mut [f32],
    lr: f32,
    momentum: f32,
    weight_decay: f32,
) {
    assert_eq!(w.len(), g.len());
    assert_eq!(w.len(), m.len());
    for ((wi, &gi), mi) in w.iter_mut().zip(g).zip(m.iter_mut()) {
        let upd = lr * (gi + weight_decay * *wi);
        *mi = momentum * *mi + upd;
        *wi -= *mi;
    }
}

/// Momentum-SGD over a list of tensors.
pub fn sgd_step_all(
    weights: &mut [Vec<f32>],
    grads: &[Vec<f32>],
    momenta: &mut [Vec<f32>],
    lr: f32,
    momentum: f32,
    weight_decay: f32,
) {
    assert_eq!(weights.len(), grads.len());
    for ((w, g), m) in weights.iter_mut().zip(grads).zip(momenta.iter_mut()) {
        sgd_step(w, g, m, lr, momentum, weight_decay);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plain_sgd_step() {
        let mut w = vec![1.0f32, 2.0];
        let g = vec![0.5f32, -0.5];
        let mut m = vec![0.0f32; 2];
        sgd_step(&mut w, &g, &mut m, 0.1, 0.0, 0.0);
        assert!((w[0] - 0.95).abs() < 1e-7);
        assert!((w[1] - 2.05).abs() < 1e-7);
    }

    #[test]
    fn weight_decay_pulls_toward_zero() {
        let mut w = vec![1.0f32];
        let mut m = vec![0.0f32];
        sgd_step(&mut w, &[0.0], &mut m, 0.1, 0.0, 0.5);
        assert!(w[0] < 1.0);
    }

    #[test]
    fn equals_lars_when_trust_is_one() {
        // LARS with zero-norm grad falls back to trust 1.0 == plain SGD.
        let mut w1 = vec![1.0f32, -2.0];
        let mut m1 = vec![0.1f32, 0.2];
        let mut w2 = w1.clone();
        let mut m2 = m1.clone();
        let g = vec![0.0f32, 0.0];
        sgd_step(&mut w1, &g, &mut m1, 0.3, 0.9, 0.0);
        let cfg = crate::optim::lars::LarsConfig {
            weight_decay: 0.0,
            ..Default::default()
        };
        crate::optim::lars::lars_step(&mut w2, &g, &mut m2, 0.3, 0.9, &cfg);
        assert_eq!(w1, w2);
        assert_eq!(m1, m2);
    }
}
