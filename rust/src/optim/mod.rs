//! Optimizers: the Rust-side LARS reference (cross-checked against the
//! Pallas kernel through the AOT artifacts) and a momentum-SGD baseline.

pub mod lars;
pub mod sgd;

pub use lars::{lars_step, lars_step_all, trust_ratio, LarsConfig};
pub use sgd::{sgd_step, sgd_step_all};
