//! Cluster topology: logical 2D grids (paper Table 4) and their placement
//! onto ABCI-like nodes (4 GPUs/node, NVLink2 intra, InfiniBand EDR inter).

pub mod grid;
pub mod placement;

pub use grid::{best_grid, table4_grid, Grid, TABLE4_GRIDS};
pub use placement::{LinkClass, Placement};
