//! Logical-grid → physical-cluster placement and link classification.
//!
//! ABCI (paper §3.1 hardware): 4 Tesla V100 per node on NVLink2; nodes on
//! 2× InfiniBand EDR. A collective step between two ranks therefore crosses
//! either an intra-node (NVLink) or an inter-node (IB) link — with very
//! different α/β — so scaling efficiency depends on *where* the logical
//! grid's rings land physically. This module maps logical ranks to
//! (node, local-gpu) slots and classifies each logical edge; `simnet::cost`
//! consumes the classification.

use super::grid::Grid;

/// Physical link class between two ranks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LinkClass {
    /// Same GPU (self-edge; zero cost).
    Local,
    /// Same node, NVLink2.
    IntraNode,
    /// Different node, InfiniBand.
    InterNode,
}

/// Placement of logical ranks onto nodes of `gpus_per_node` GPUs.
///
/// The default ("packed rows") policy fills nodes along the horizontal
/// dimension first — exactly what you want for a 2D-torus: with
/// `x % gpus_per_node == 0`, all horizontal ring hops except the node
/// boundaries stay on NVLink and the whole vertical phase rides IB.
#[derive(Debug, Clone)]
pub struct Placement {
    pub grid: Grid,
    pub gpus_per_node: usize,
}

impl Placement {
    pub fn packed(grid: Grid, gpus_per_node: usize) -> Self {
        assert!(gpus_per_node > 0);
        Self {
            grid,
            gpus_per_node,
        }
    }

    pub fn node_of(&self, rank: usize) -> usize {
        rank / self.gpus_per_node
    }

    pub fn nodes(&self) -> usize {
        self.grid.ranks().div_ceil(self.gpus_per_node)
    }

    pub fn classify(&self, a: usize, b: usize) -> LinkClass {
        if a == b {
            LinkClass::Local
        } else if self.node_of(a) == self.node_of(b) {
            LinkClass::IntraNode
        } else {
            LinkClass::InterNode
        }
    }

    /// Fraction of a horizontal ring's hops that stay intra-node.
    pub fn horizontal_intra_fraction(&self) -> f64 {
        let g = &self.grid;
        if g.x <= 1 {
            return 1.0;
        }
        let mut intra = 0usize;
        let mut total = 0usize;
        // All rows have identical structure under packed placement only if
        // x % gpus_per_node == 0; count row 0 and the general case both by
        // brute force over every row (cheap, done once).
        for y in 0..g.y {
            for x in 0..g.x {
                let a = g.rank(x, y);
                let b = g.right(a);
                total += 1;
                if self.classify(a, b) == LinkClass::IntraNode {
                    intra += 1;
                }
            }
        }
        intra as f64 / total as f64
    }

    /// Fraction of a vertical ring's hops that stay intra-node.
    pub fn vertical_intra_fraction(&self) -> f64 {
        let g = &self.grid;
        if g.y <= 1 {
            return 1.0;
        }
        let mut intra = 0usize;
        let mut total = 0usize;
        for y in 0..g.y {
            for x in 0..g.x {
                let a = g.rank(x, y);
                let b = g.down(a);
                total += 1;
                if self.classify(a, b) == LinkClass::IntraNode {
                    intra += 1;
                }
            }
        }
        intra as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn node_assignment_packs_ranks() {
        let p = Placement::packed(Grid::new(8, 2), 4);
        assert_eq!(p.node_of(0), 0);
        assert_eq!(p.node_of(3), 0);
        assert_eq!(p.node_of(4), 1);
        assert_eq!(p.nodes(), 4);
    }

    #[test]
    fn classify_edges() {
        let p = Placement::packed(Grid::new(8, 2), 4);
        assert_eq!(p.classify(0, 0), LinkClass::Local);
        assert_eq!(p.classify(0, 1), LinkClass::IntraNode);
        assert_eq!(p.classify(3, 4), LinkClass::InterNode);
    }

    #[test]
    fn packed_rows_keep_horizontal_mostly_intra() {
        // 8 wide rows over 4-GPU nodes: hops 0-1,1-2,2-3 intra; 3-4 inter;
        // 4-5,5-6,6-7 intra; 7-0 inter => 6/8 intra.
        let p = Placement::packed(Grid::new(8, 2), 4);
        assert!((p.horizontal_intra_fraction() - 0.75).abs() < 1e-12);
        // vertical hops always cross nodes here
        assert_eq!(p.vertical_intra_fraction(), 0.0);
    }

    #[test]
    fn single_node_cluster_is_all_nvlink() {
        let p = Placement::packed(Grid::new(2, 2), 4);
        assert_eq!(p.horizontal_intra_fraction(), 1.0);
        assert_eq!(p.vertical_intra_fraction(), 1.0);
    }

    #[test]
    fn degenerate_dims() {
        let p = Placement::packed(Grid::new(1, 4), 4);
        assert_eq!(p.horizontal_intra_fraction(), 1.0);
        // column of 4 on one node
        assert_eq!(p.vertical_intra_fraction(), 1.0);
    }
}
