//! 2D-grid shapes for the torus (paper Table 4) and rank↔coordinate maps.
//!
//! The paper arranges N GPUs in a V (vertical) × H (horizontal) logical
//! grid; Table 4 lists the shapes used on ABCI. `rank = y * H + x`
//! (row-major), matching `collectives::torus2d`.

/// A logical 2D grid: `x` horizontal ranks per row, `y` vertical rows.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Grid {
    /// Horizontal extent (ranks per row; the paper's "Horizontal").
    pub x: usize,
    /// Vertical extent (rows; the paper's "Vertical").
    pub y: usize,
}

impl Grid {
    pub fn new(x: usize, y: usize) -> Self {
        assert!(x > 0 && y > 0);
        Self { x, y }
    }

    pub fn ranks(&self) -> usize {
        self.x * self.y
    }

    /// (x, y) coordinate of `rank`.
    pub fn coord(&self, rank: usize) -> (usize, usize) {
        assert!(rank < self.ranks());
        (rank % self.x, rank / self.x)
    }

    pub fn rank(&self, x: usize, y: usize) -> usize {
        assert!(x < self.x && y < self.y);
        y * self.x + x
    }

    /// Right neighbour on the horizontal ring.
    pub fn right(&self, rank: usize) -> usize {
        let (x, y) = self.coord(rank);
        self.rank((x + 1) % self.x, y)
    }

    /// Down neighbour on the vertical ring.
    pub fn down(&self, rank: usize) -> usize {
        let (x, y) = self.coord(rank);
        self.rank(x, (y + 1) % self.y)
    }
}

/// The grid dimensions from paper Table 4, keyed by GPU count:
/// (vertical, horizontal).
pub const TABLE4_GRIDS: &[(usize, usize, usize)] = &[
    // (#GPUs, Vertical, Horizontal)
    (1024, 32, 32),
    (2048, 32, 64),
    (2176, 34, 64),
    (3456, 48, 72),
    (4096, 64, 64),
];

/// Grid from Table 4 if the paper lists one for `n`.
pub fn table4_grid(n: usize) -> Option<Grid> {
    TABLE4_GRIDS
        .iter()
        .find(|&&(gpus, _, _)| gpus == n)
        .map(|&(_, v, h)| Grid::new(h, v))
}

/// Most-square factorisation of `n` (x >= y), preferring the paper's
/// published shape when `n` appears in Table 4.
///
/// Minimising `x + y` minimises the torus latency term `2(X-1) + 2(Y-1)`,
/// which is why the paper's own grids are near-square.
pub fn best_grid(n: usize) -> (usize, usize) {
    assert!(n > 0);
    if let Some(g) = table4_grid(n) {
        return (g.x, g.y);
    }
    let mut best = (n, 1);
    let mut y = 1usize;
    while y * y <= n {
        if n % y == 0 {
            best = (n / y, y); // x >= y; later (larger) y is more square
        }
        y += 1;
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn coords_round_trip() {
        let g = Grid::new(4, 3);
        for rank in 0..g.ranks() {
            let (x, y) = g.coord(rank);
            assert_eq!(g.rank(x, y), rank);
        }
    }

    #[test]
    fn neighbours_wrap() {
        let g = Grid::new(3, 2);
        assert_eq!(g.right(2), 0); // (2,0) -> (0,0)
        assert_eq!(g.right(0), 1);
        assert_eq!(g.down(4), 1); // (1,1) -> (1,0)
        assert_eq!(g.down(1), 4);
    }

    #[test]
    fn table4_shapes_multiply_out() {
        for &(n, v, h) in TABLE4_GRIDS {
            assert_eq!(v * h, n, "Table 4 row for {n} GPUs");
            let g = table4_grid(n).unwrap();
            assert_eq!(g.ranks(), n);
            assert_eq!((g.y, g.x), (v, h));
        }
        assert!(table4_grid(123).is_none());
    }

    #[test]
    fn best_grid_is_square_ish_and_exact() {
        assert_eq!(best_grid(16), (4, 4));
        assert_eq!(best_grid(8), (4, 2));
        assert_eq!(best_grid(7), (7, 1));
        assert_eq!(best_grid(1), (1, 1));
        assert_eq!(best_grid(12), (4, 3));
        // Table 4 overrides: 2048 is (64, 32), not (64, 32) from search —
        // same here, but 2176's natural best is (68, 32); paper says (64, 34).
        assert_eq!(best_grid(2176), (64, 34));
    }

    #[test]
    fn best_grid_latency_dominates_flat_ring() {
        for n in [64usize, 256, 1024, 4096] {
            let (x, y) = best_grid(n);
            assert!(2 * (x - 1) + 2 * (y - 1) < 2 * (n - 1));
        }
    }
}
