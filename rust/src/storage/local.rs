//! Local-directory [`StorageBackend`]: one object per file under a root
//! directory, with the same tmp-write + fsync + rename discipline as
//! `coordinator::checkpoint::save` so a crash mid-`put` never leaves a
//! partially visible object.

use std::fs;
use std::io::Write as _;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context as _, Result};

use super::StorageBackend;

/// Suffix of in-flight temp files; `list` hides them so a reader never
/// mistakes a write in progress for an object.
const TMP_SUFFIX: &str = ".inflight";

#[derive(Debug, Clone)]
pub struct LocalDir {
    root: PathBuf,
}

impl LocalDir {
    /// Open `root` as a store, creating the directory if needed.
    pub fn create(root: impl AsRef<Path>) -> Result<Self> {
        let root = root.as_ref().to_path_buf();
        fs::create_dir_all(&root)
            .with_context(|| format!("creating storage dir {}", root.display()))?;
        Ok(Self { root })
    }

    /// The directory this store writes into.
    pub fn root(&self) -> &Path {
        &self.root
    }

    /// Keys are single path components: a key that is empty, contains a
    /// separator, or names `.`/`..` could escape the root, so reject it
    /// here once for every verb.
    fn path_for(&self, key: &str) -> Result<PathBuf> {
        if key.is_empty()
            || key == "."
            || key == ".."
            || key.contains('/')
            || key.contains('\\')
            || key.ends_with(TMP_SUFFIX)
        {
            bail!("invalid storage key '{key}'");
        }
        Ok(self.root.join(key))
    }
}

impl StorageBackend for LocalDir {
    fn put(&self, key: &str, bytes: &[u8]) -> Result<()> {
        let path = self.path_for(key)?;
        let tmp = self.root.join(format!("{key}{TMP_SUFFIX}"));
        {
            let mut f = fs::File::create(&tmp)
                .with_context(|| format!("creating {}", tmp.display()))?;
            f.write_all(bytes)
                .with_context(|| format!("writing {}", tmp.display()))?;
            // Durability point: the bytes must be on disk *before* the
            // rename publishes them, or a crash could publish garbage.
            f.sync_all()
                .with_context(|| format!("syncing {}", tmp.display()))?;
        }
        fs::rename(&tmp, &path)
            .with_context(|| format!("publishing {}", path.display()))?;
        Ok(())
    }

    fn get(&self, key: &str) -> Result<Vec<u8>> {
        let path = self.path_for(key)?;
        fs::read(&path).with_context(|| format!("reading {}", path.display()))
    }

    fn list(&self, prefix: &str) -> Result<Vec<String>> {
        let mut keys = Vec::new();
        let entries = fs::read_dir(&self.root)
            .with_context(|| format!("listing {}", self.root.display()))?;
        for entry in entries {
            let entry = entry.with_context(|| format!("listing {}", self.root.display()))?;
            let name = match entry.file_name().into_string() {
                Ok(n) => n,
                Err(_) => continue, // non-UTF-8 names are never our objects
            };
            if name.ends_with(TMP_SUFFIX) || !name.starts_with(prefix) {
                continue;
            }
            if entry.file_type().map(|t| t.is_file()).unwrap_or(false) {
                keys.push(name);
            }
        }
        Ok(keys)
    }

    fn delete(&self, key: &str) -> Result<()> {
        let path = self.path_for(key)?;
        match fs::remove_file(&path) {
            Ok(()) => Ok(()),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(()),
            Err(e) => Err(e).with_context(|| format!("deleting {}", path.display())),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scratch(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "flashsgd-localdir-{tag}-{}",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn put_get_list_delete_round_trip() {
        let root = scratch("roundtrip");
        let store = LocalDir::create(&root).unwrap();
        store.put("snap-00000004.ckpt", b"abc").unwrap();
        store.put("snap-00000008.ckpt", b"defg").unwrap();
        store.put("other.bin", b"x").unwrap();

        assert_eq!(store.get("snap-00000004.ckpt").unwrap(), b"abc");

        let mut snaps = store.list("snap-").unwrap();
        snaps.sort();
        assert_eq!(snaps, vec!["snap-00000004.ckpt", "snap-00000008.ckpt"]);

        store.delete("snap-00000004.ckpt").unwrap();
        // Deleting a missing key is fine — GC races are benign.
        store.delete("snap-00000004.ckpt").unwrap();
        assert_eq!(store.list("snap-").unwrap(), vec!["snap-00000008.ckpt"]);

        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn put_replaces_atomically_and_hides_inflight() {
        let root = scratch("atomic");
        let store = LocalDir::create(&root).unwrap();
        store.put("obj", b"v1").unwrap();
        store.put("obj", b"v2-longer").unwrap();
        assert_eq!(store.get("obj").unwrap(), b"v2-longer");

        // A stale in-flight temp (crash mid-put) is invisible to list.
        fs::write(root.join(format!("torn{TMP_SUFFIX}")), b"partial").unwrap();
        assert_eq!(store.list("").unwrap(), vec!["obj"]);

        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn rejects_escaping_keys() {
        let root = scratch("keys");
        let store = LocalDir::create(&root).unwrap();
        for bad in ["", ".", "..", "a/b", "a\\b", "x.inflight"] {
            assert!(store.put(bad, b"x").is_err(), "key '{bad}' must be rejected");
        }
        let _ = fs::remove_dir_all(&root);
    }
}
