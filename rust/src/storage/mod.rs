//! Pluggable checkpoint/snapshot storage (ROADMAP item 4's I/O plane).
//!
//! The durability layer never talks to the filesystem directly — it goes
//! through [`StorageBackend`], a four-verb object store shaped like S3
//! (`put`/`get`/`list`/`delete` on flat string keys). [`LocalDir`] is the
//! only implementation today; an S3-shaped backend can slot in later
//! without touching the snapshot or resume code.
//!
//! Writes are where durability lives, so they get two extra guarantees:
//!
//! * **Atomicity** — [`LocalDir::put`] writes a temp file, fsyncs it, and
//!   renames it into place, so a crash mid-write can never leave a
//!   half-visible object (a torn snapshot shows up as *absent*, not
//!   corrupt — though resume tolerates corrupt too; see
//!   `coordinator::journal`).
//! * **Retry** — [`put_with_retry`] wraps `put` in the PR-6
//!   [`BackoffConfig`] jittered-backoff loop, so a transient I/O error
//!   (full pipe, NFS hiccup) costs a delay instead of a lost snapshot.

use std::time::Duration;

use anyhow::Result;

use crate::collectives::transport::BackoffConfig;

pub mod local;

pub use local::LocalDir;

/// Deterministic-jitter salt for snapshot-write retries (cf. the dial
/// salt `0x10_1D` in `coordinator::remote`).
const PUT_RETRY_SALT: u64 = 0x57_0F_A6E;

/// A flat key/value object store. Keys are plain relative names (no `/`
/// semantics are promised beyond what [`list`](StorageBackend::list)'s
/// prefix match gives you); values are opaque byte blobs.
///
/// Implementations must be safe to call from a background thread while
/// the training loop runs — the snapshotter holds one behind an `Arc`.
pub trait StorageBackend: Send + Sync {
    /// Store `bytes` under `key`, replacing any existing object. Must be
    /// atomic: a reader (or a crash) sees either the old object or the
    /// complete new one, never a prefix.
    fn put(&self, key: &str, bytes: &[u8]) -> Result<()>;

    /// Fetch the object under `key`.
    fn get(&self, key: &str) -> Result<Vec<u8>>;

    /// All keys starting with `prefix`, in unspecified order.
    fn list(&self, prefix: &str) -> Result<Vec<String>>;

    /// Remove the object under `key`. Deleting a missing key is not an
    /// error (delete is the GC verb; races with a concurrent GC are
    /// benign).
    fn delete(&self, key: &str) -> Result<()>;
}

/// `put` with the transport's jittered exponential backoff on failure.
/// Returns the number of attempts that were needed (1 = first try).
pub fn put_with_retry(
    backend: &dyn StorageBackend,
    key: &str,
    bytes: &[u8],
    backoff: &BackoffConfig,
) -> Result<u32> {
    let mut last_err = None;
    for attempt in 0..backoff.attempts.max(1) {
        match backend.put(key, bytes) {
            Ok(()) => return Ok(attempt + 1),
            Err(e) => {
                last_err = Some(e);
                if attempt + 1 < backoff.attempts.max(1) {
                    std::thread::sleep(backoff.delay(attempt, PUT_RETRY_SALT));
                }
            }
        }
    }
    Err(last_err
        .unwrap_or_else(|| anyhow::anyhow!("storage put failed with zero attempts configured"))
        .context(format!("storing '{key}' after {} attempts", backoff.attempts.max(1))))
}

/// Resolve a storage spec to a backend. A plain path or a `file://` URL
/// maps to [`LocalDir`] (created if absent); any other scheme is
/// rejected here, in one place, so adding `s3://` later is a one-arm
/// change.
pub fn open_backend(spec: &str) -> Result<Box<dyn StorageBackend>> {
    if let Some(rest) = spec.strip_prefix("file://") {
        return Ok(Box::new(LocalDir::create(rest)?));
    }
    if let Some((scheme, _)) = spec.split_once("://") {
        anyhow::bail!("unsupported storage scheme '{scheme}://' (only local paths and file:// are available)");
    }
    Ok(Box::new(LocalDir::create(spec)?))
}

/// The local filesystem path behind a storage spec (a plain path or a
/// `file://` URL) — where file-bound artifacts like the run journal
/// live, next to the backend's objects.
pub fn local_path(spec: &str) -> &std::path::Path {
    std::path::Path::new(spec.strip_prefix("file://").unwrap_or(spec))
}

/// A conservative backoff for snapshot writes: fewer attempts than a
/// dial (the run can make progress without this snapshot; the *next*
/// one will try again) but the same growth curve.
pub fn snapshot_backoff() -> BackoffConfig {
    BackoffConfig {
        base: Duration::from_millis(50),
        max: Duration::from_millis(1000),
        attempts: 5,
        jitter: 0.25,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU32, Ordering};
    use std::sync::Mutex;

    /// A backend that fails the first `fail_first` puts, for retry tests.
    struct Flaky {
        fail_first: u32,
        calls: AtomicU32,
        stored: Mutex<Vec<(String, Vec<u8>)>>,
    }

    impl StorageBackend for Flaky {
        fn put(&self, key: &str, bytes: &[u8]) -> Result<()> {
            let n = self.calls.fetch_add(1, Ordering::SeqCst);
            if n < self.fail_first {
                anyhow::bail!("injected put failure #{n}");
            }
            self.stored.lock().unwrap().push((key.to_string(), bytes.to_vec()));
            Ok(())
        }
        fn get(&self, _key: &str) -> Result<Vec<u8>> {
            anyhow::bail!("not used")
        }
        fn list(&self, _prefix: &str) -> Result<Vec<String>> {
            Ok(Vec::new())
        }
        fn delete(&self, _key: &str) -> Result<()> {
            Ok(())
        }
    }

    fn fast_backoff(attempts: u32) -> BackoffConfig {
        BackoffConfig {
            base: Duration::from_millis(1),
            max: Duration::from_millis(2),
            attempts,
            jitter: 0.0,
        }
    }

    #[test]
    fn put_with_retry_survives_transient_failures() {
        let b = Flaky {
            fail_first: 2,
            calls: AtomicU32::new(0),
            stored: Mutex::new(Vec::new()),
        };
        let attempts = put_with_retry(&b, "k", b"v", &fast_backoff(5)).unwrap();
        assert_eq!(attempts, 3);
        let stored = b.stored.lock().unwrap();
        assert_eq!(stored.as_slice(), &[("k".to_string(), b"v".to_vec())]);
    }

    #[test]
    fn put_with_retry_gives_up_after_budget() {
        let b = Flaky {
            fail_first: u32::MAX,
            calls: AtomicU32::new(0),
            stored: Mutex::new(Vec::new()),
        };
        let err = put_with_retry(&b, "k", b"v", &fast_backoff(3)).unwrap_err();
        assert!(err.to_string().contains("storing 'k' after 3 attempts"), "{err}");
        assert_eq!(b.calls.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn open_backend_rejects_unknown_schemes() {
        let err = open_backend("s3://bucket/prefix").unwrap_err();
        assert!(err.to_string().contains("unsupported storage scheme 's3://'"), "{err}");
    }

    #[test]
    fn open_backend_accepts_paths_and_file_urls() {
        let dir = std::env::temp_dir().join(format!("flashsgd-storage-open-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let plain = open_backend(dir.join("plain").to_str().unwrap()).unwrap();
        plain.put("a", b"1").unwrap();
        let url = open_backend(&format!("file://{}", dir.join("url").display())).unwrap();
        url.put("b", b"2").unwrap();
        assert_eq!(plain.get("a").unwrap(), b"1");
        assert_eq!(url.get("b").unwrap(), b"2");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
