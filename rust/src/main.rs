//! `flashsgd` — the leader binary.
//!
//! Subcommands:
//!   train       run a training job (preset, twin, or TOML config)
//!   coordinator   lead a multi-process run (workers join over TCP)
//!   worker      join a coordinator and serve phase assignments
//!   simulate    ABCI-scale step-time / throughput projection
//!   reproduce   print a paper table (--table 1..6)
//!   demo        topology / all-reduce walkthroughs (figure 1 & 2)
//!   list-configs  show the paper's Table 3 presets
//!
//! Examples:
//!   flashsgd train --preset quickstart
//!   flashsgd train --twin exp2 --ranks 8 --epochs 4 --arch tiny
//!   flashsgd train --config configs/exp2_twin.toml
//!   flashsgd coordinator --config configs/smoke.toml --save run.ckpt
//!   flashsgd worker --join 127.0.0.1:7070
//!   flashsgd simulate --gpus 1024 --collective torus
//!   flashsgd reproduce --table 6

use anyhow::{anyhow, bail, Context, Result};

use flashsgd::cluster::best_grid;
use flashsgd::config::{paper_run, TrainConfig};
use flashsgd::coordinator::Trainer;
use flashsgd::repro;
use flashsgd::simnet::{
    Algo, ClusterModel, RESNET50_BN_BYTES_FP32, RESNET50_GRAD_BYTES_FP16,
};
use flashsgd::util::toml::Doc;

/// Minimal flag parser: `--key value` pairs after the subcommand.
struct Args {
    cmd: String,
    positional: Vec<String>,
    flags: Vec<(String, String)>,
}

impl Args {
    fn parse() -> Result<Self> {
        let mut it = std::env::args().skip(1);
        let cmd = it.next().unwrap_or_else(|| "help".to_string());
        let mut flags = Vec::new();
        let mut positional = Vec::new();
        while let Some(k) = it.next() {
            if let Some(key) = k.strip_prefix("--") {
                let val = it
                    .next()
                    .ok_or_else(|| anyhow!("flag --{key} needs a value"))?;
                flags.push((key.to_string(), val));
            } else {
                positional.push(k);
            }
        }
        Ok(Self {
            cmd,
            positional,
            flags,
        })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags
            .iter()
            .rev()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }

    fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key} {v:?}")),
            None => Ok(default),
        }
    }
}

/// Backend selection: default build trains on the pure-Rust reference
/// backend; with `--features pjrt`, an artifacts directory (from
/// `--artifacts` / `$FLASHSGD_ARTIFACTS` / `./artifacts`) switches to PJRT.
#[cfg(feature = "pjrt")]
fn make_trainer(config: TrainConfig, args: &Args) -> Result<Trainer> {
    if let Some(dir) = args.get("artifacts") {
        // An explicit --artifacts is a request for PJRT: a missing or
        // invalid manifest is an error, never a silent fallback.
        return Trainer::with_pjrt(config, dir);
    }
    let dir = flashsgd::artifacts_dir();
    if dir.join("manifest.json").exists() {
        Trainer::with_pjrt(config, dir)
    } else {
        Trainer::new(config)
    }
}

#[cfg(not(feature = "pjrt"))]
fn make_trainer(config: TrainConfig, args: &Args) -> Result<Trainer> {
    if args.get("artifacts").is_some() {
        bail!(
            "--artifacts requires the PJRT backend; rebuild with \
             `--features pjrt` (the default build trains on the pure-Rust \
             reference backend)"
        );
    }
    Trainer::new(config)
}

fn main() -> Result<()> {
    let args = Args::parse()?;
    match args.cmd.as_str() {
        "train" => cmd_train(&args),
        "coordinator" => cmd_coordinator(&args),
        "worker" => cmd_worker(&args),
        "simulate" => cmd_simulate(&args),
        "reproduce" => cmd_reproduce(&args),
        "demo" => cmd_demo(&args),
        "list-configs" => {
            print!("{}", repro::table3());
            Ok(())
        }
        "help" | "--help" | "-h" => {
            print!("{}", HELP);
            Ok(())
        }
        other => bail!("unknown subcommand {other:?}\n{HELP}"),
    }
}

const HELP: &str = "\
flashsgd — Massively Distributed SGD reproduction (Sony 2018)

USAGE:
  flashsgd train [--preset quickstart | --twin <run> | --config <file>]
                 [--ranks N] [--epochs E] [--arch tiny]
                 [--steps N] [--collective torus|ring|hierarchical:<g>|halving-doubling]
                 [--csv out.csv] [--save ckpt] [--resume <ckpt|durable-dir>]
                 [--artifacts DIR   (pjrt feature only; default backend is pure Rust)]
  flashsgd coordinator --config <file> [--bind addr] [--http addr] [--save ckpt]
                       [--resume <ckpt|durable-dir>   (replay journal + newest snapshot)]
  flashsgd worker [--join addr   (default 127.0.0.1:7070)]
  flashsgd simulate [--gpus N] [--batch B] [--collective ...]
  flashsgd reproduce --table 1|2|3|4|5|6
  flashsgd demo topology|allreduce [--x X] [--y Y]
  flashsgd list-configs
";

fn cmd_train(args: &Args) -> Result<()> {
    let mut config = if let Some(path) = args.get("config") {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        TrainConfig::from_toml(&Doc::parse(&text)?)?
    } else if let Some(name) = args.get("twin") {
        let run = paper_run(name).ok_or_else(|| anyhow!("unknown paper run {name:?}"))?;
        let ranks = args.usize_or("ranks", 8)?;
        let epochs = args.usize_or("epochs", 4)? as u32;
        let arch = args.get("arch").unwrap_or("tiny");
        TrainConfig::twin_of(&run, ranks, arch, epochs)
    } else {
        TrainConfig::quickstart()
    };
    if let Some(spec) = args.get("collective") {
        config.collective = spec.to_string();
    }
    if let Some(steps) = args.get("steps") {
        config.max_steps = steps.parse().context("--steps")?;
    }

    eprintln!(
        "[flashsgd] run {:?}: arch={} collective={} workers(max)={} epochs={}",
        config.name,
        config.arch,
        config.collective,
        config.batch.max_workers(),
        config.batch.total_epochs
    );
    let mut trainer = make_trainer(config, args)?;
    if let Some(path) = args.get("save") {
        trainer = trainer.with_checkpoint(path);
    }
    if let Some(path) = args.get("resume") {
        trainer = trainer.with_resume(path);
    }
    let report = trainer.run()?;
    println!("{}", report.format());
    for (step, loss) in report.metrics.loss_curve(10) {
        println!("  step {step:>5}  loss {loss:.4}");
    }
    if let Some(path) = args.get("csv") {
        std::fs::write(path, report.metrics.to_csv())?;
        eprintln!("[flashsgd] wrote {path}");
    }
    if let Some(path) = args.get("json") {
        std::fs::write(path, report.metrics.to_json().to_string())?;
        eprintln!("[flashsgd] wrote {path}");
    }
    Ok(())
}

/// Lead a multi-process run: parse the config, bind the control socket
/// (`transport.bind`, overridable with `--bind`), wait for the schedule's
/// worker count to join, and drive the phases. The config TOML text is
/// shipped verbatim to every worker, so the whole cluster trains one
/// configuration from one file.
fn cmd_coordinator(args: &Args) -> Result<()> {
    let path = args
        .get("config")
        .ok_or_else(|| anyhow!("coordinator requires --config <file>"))?;
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    let mut config = TrainConfig::from_toml(&Doc::parse(&text)?)?;
    if let Some(bind) = args.get("bind") {
        config.transport.bind = bind.to_string();
    }
    if let Some(http) = args.get("http") {
        config.transport.http = http.to_string();
    }
    let save = args.get("save").map(std::path::Path::new);
    let resume = args.get("resume").map(std::path::Path::new);
    let report = flashsgd::coordinator::remote::run_coordinator(&config, &text, save, resume)?;
    println!("{}", report.format());
    for (step, loss) in report.metrics.loss_curve(10) {
        println!("  step {step:>5}  loss {loss:.4}");
    }
    if let Some(path) = args.get("csv") {
        std::fs::write(path, report.metrics.to_csv())?;
        eprintln!("[flashsgd] wrote {path}");
    }
    Ok(())
}

/// Join a coordinator as one worker process and serve phase assignments
/// until it says shutdown.
fn cmd_worker(args: &Args) -> Result<()> {
    let join = args.get("join").unwrap_or("127.0.0.1:7070");
    flashsgd::coordinator::remote::run_worker(join)
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let gpus = args.usize_or("gpus", 1024)?;
    let batch = args.usize_or("batch", 32)?;
    let m = ClusterModel::abci_v100();
    let algos: Vec<Algo> = match args.get("collective") {
        Some("ring") => vec![Algo::Ring],
        Some("hierarchical") => vec![Algo::Hierarchical { group: 4 }],
        Some(spec) if spec.starts_with("torus") => {
            let (x, y) = best_grid(gpus);
            vec![Algo::Torus { x, y }]
        }
        _ => {
            let (x, y) = best_grid(gpus);
            vec![
                Algo::Torus { x, y },
                Algo::Hierarchical { group: 4 },
                Algo::Ring,
            ]
        }
    };
    println!("simulate: {gpus} GPUs, {batch}/worker, ResNet-50 FP16 grads");
    for algo in algos {
        let st = m.step_time(
            algo,
            gpus,
            batch,
            RESNET50_GRAD_BYTES_FP16,
            RESNET50_BN_BYTES_FP32,
        );
        let thr = (gpus * batch) as f64 / st.total_secs();
        println!(
            "  {:<22} step {:>8.2} ms  (compute {:.2} + grad-comm {:.2} + bn-comm {:.2})  {:>12.0} img/s",
            algo.name(),
            st.total_secs() * 1e3,
            st.compute_secs * 1e3,
            st.grad_comm_secs * 1e3,
            st.bn_comm_secs * 1e3,
            thr
        );
    }
    Ok(())
}

fn cmd_reproduce(args: &Args) -> Result<()> {
    let table = args.usize_or("table", 6)?;
    let out = match table {
        1 => repro::table1(),
        2 => repro::table2(),
        3 => repro::table3(),
        4 => repro::table4(),
        5 => repro::table5(),
        6 => repro::table6(),
        n => bail!("no table {n} in the paper (1-6)"),
    };
    print!("{out}");
    Ok(())
}

fn cmd_demo(args: &Args) -> Result<()> {
    let what = args
        .positional
        .first()
        .map(|s| s.as_str())
        .or_else(|| args.get("what"))
        .unwrap_or("topology");
    let x = args.usize_or("x", 4)?;
    let y = args.usize_or("y", 2)?;
    match what {
        "topology" => print!("{}", repro::figure1(x, y)),
        "allreduce" => {
            // Figure 2 walkthrough lives in examples/torus_demo.rs (it
            // drives the real collective); point there.
            println!("run: cargo run --release --example torus_demo");
        }
        other => bail!("unknown demo {other:?} (topology | allreduce)"),
    }
    Ok(())
}
