//! Transport conformance: the loopback-TCP mesh must be observationally
//! identical to the in-memory mesh under every collective schedule.
//!
//! "Identical" is strict on three axes:
//!   * **results** — bit-for-bit equal reduced vectors on every rank
//!     (the schedules fix the reduction order, so not even the last ULP
//!     may differ between transports);
//!   * **traffic** — equal `Counters` snapshots (bytes sent/received,
//!     message count). Counters bill logical payload bytes only, never
//!     frame headers, so a divergence means a schedule took a different
//!     path over one transport;
//!   * **tags** — equal `max_tag_seen`, pinning the tag windows to the
//!     same layout on both transports.
//!
//! Payload lengths and values come from a seeded xorshift generator so
//! each (schedule, world) case exercises a different shape, including
//! lengths that do not divide evenly by the world size.

use std::sync::Arc;
use std::thread;

use flashsgd::collectives::bucketed::all_reduce_buckets;
use flashsgd::collectives::{by_name, Collective, Mesh, TcpMesh, Transport, Wire};

/// Deterministic xorshift64* — the tests must not depend on crate-external
/// randomness, only on reproducible per-case streams.
struct Rng(u64);

impl Rng {
    fn new(seed: u64) -> Self {
        Rng(seed.wrapping_mul(2685821657736338717).max(1))
    }

    fn next(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.0 = x;
        x.wrapping_mul(2685821657736338717)
    }

    /// Uniform in `lo..hi`.
    fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + (self.next() as usize) % (hi - lo)
    }

    /// Small, FP16-exact magnitudes, so the F16 wire cases stay
    /// bit-comparable without the generator having to know the wire.
    fn f32(&mut self) -> f32 {
        let q = (self.next() % 513) as f32 - 256.0;
        q * 0.03125
    }
}

/// Per-rank input vector for one case: every rank derives its slice from
/// the shared seed so both transports see byte-identical operands.
fn inputs(seed: u64, n: usize, elems: usize) -> Vec<Vec<f32>> {
    (0..n)
        .map(|rank| {
            let mut rng = Rng::new(seed ^ ((rank as u64 + 1) << 32));
            (0..elems).map(|_| rng.f32()).collect()
        })
        .collect()
}

/// Drive `coll` once over a set of connected endpoints (one thread per
/// rank, exactly like the worker pool) and report everything the
/// conformance check compares: per-rank results, the counter snapshot,
/// and the highest tag seen.
fn run_schedule<T: Transport + Send + 'static>(
    eps: Vec<T>,
    coll: &Arc<dyn Collective>,
    ins: &[Vec<f32>],
    wire: Wire,
) -> (Vec<Vec<f32>>, (u64, u64, u64), u64) {
    let counters = eps[0].counters_arc();
    let handles: Vec<_> = eps
        .into_iter()
        .map(|mut ep| {
            let coll = coll.clone();
            let mut buf = ins[ep.rank()].clone();
            thread::spawn(move || {
                coll.all_reduce(&mut ep, &mut buf, wire, 0).unwrap();
                buf
            })
        })
        .collect();
    let results: Vec<Vec<f32>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    (results, counters.snapshot(), counters.max_tag_seen())
}

/// Same, but through the bucketed streaming pipeline: each rank reduces a
/// list of per-bucket flats back-to-back, advancing the tag window one
/// span per bucket — the exact traffic pattern of an overlapped step.
fn run_buckets<T: Transport + Send + 'static>(
    eps: Vec<T>,
    coll: &Arc<dyn Collective>,
    ins: &[Vec<Vec<f32>>],
    wire: Wire,
) -> (Vec<Vec<Vec<f32>>>, (u64, u64, u64), u64, u64) {
    let counters = eps[0].counters_arc();
    let handles: Vec<_> = eps
        .into_iter()
        .map(|mut ep| {
            let coll = coll.clone();
            let mut bufs = ins[ep.rank()].clone();
            thread::spawn(move || {
                let next = all_reduce_buckets(&*coll, &mut ep, &mut bufs, wire, 0).unwrap();
                (bufs, next)
            })
        })
        .collect();
    let joined: Vec<(Vec<Vec<f32>>, u64)> =
        handles.into_iter().map(|h| h.join().unwrap()).collect();
    let next_tag = joined[0].1;
    let results: Vec<Vec<Vec<f32>>> = joined.into_iter().map(|(bufs, _)| bufs).collect();
    (results, counters.snapshot(), counters.max_tag_seen(), next_tag)
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Every schedule × a world size it supports. Worlds are kept small
/// enough that the full O(n²) loopback socket mesh stays cheap.
fn cases() -> Vec<(&'static str, usize)> {
    vec![
        ("ring", 4),
        ("ring", 6),
        ("halving-doubling", 4),
        ("halving-doubling", 8),
        ("hierarchical:2", 8),
        ("hierarchical:4", 8),
        ("torus:2x2", 4),
        ("torus:4x2", 8),
        ("torus:3x3", 9),
    ]
}

#[test]
fn tcp_matches_memory_bit_for_bit_on_every_schedule() {
    for (ci, (spec, n)) in cases().into_iter().enumerate() {
        for wire in [Wire::F32, Wire::F16] {
            let seed = 0x5EED_0001 + ci as u64 * 131 + matches!(wire, Wire::F16) as u64;
            let mut rng = Rng::new(seed);
            // Lengths deliberately include awkward residues: a prime-ish
            // random size plus one tiny vector shorter than the world.
            for elems in [rng.range(64, 512) | 1, rng.range(1, n)] {
                let ins = inputs(seed ^ elems as u64, n, elems);
                let coll: Arc<dyn Collective> = Arc::from(by_name(spec, n).unwrap());

                let (mem_out, mem_ctr, mem_tag) =
                    run_schedule(Mesh::new(n), &coll, &ins, wire);
                let (tcp_out, tcp_ctr, tcp_tag) =
                    run_schedule(TcpMesh::loopback(n).unwrap(), &coll, &ins, wire);

                let what = format!("{spec} n={n} elems={elems} wire={wire:?}");
                for (rank, (m, t)) in mem_out.iter().zip(&tcp_out).enumerate() {
                    assert_eq!(
                        bits(m),
                        bits(t),
                        "{what}: rank {rank} diverges between transports"
                    );
                }
                assert_eq!(
                    mem_ctr, tcp_ctr,
                    "{what}: traffic counters differ (memory {mem_ctr:?} vs tcp {tcp_ctr:?})"
                );
                assert_eq!(
                    mem_tag, tcp_tag,
                    "{what}: max tag differs (memory {mem_tag} vs tcp {tcp_tag})"
                );
            }
        }
    }
}

#[test]
fn bucketed_pipeline_is_transport_invariant() {
    // One representative world per schedule family; the bucket pipeline
    // stacks a full tag window per bucket, so this also cross-checks the
    // per-span tag accounting over real sockets.
    for (ci, (spec, n)) in [
        ("ring", 4usize),
        ("halving-doubling", 4),
        ("hierarchical:2", 4),
        ("torus:2x2", 4),
    ]
    .into_iter()
    .enumerate()
    {
        let seed = 0x00B0_C4E7 + ci as u64 * 977;
        let mut rng = Rng::new(seed);
        let n_buckets = rng.range(2, 5);
        let shapes: Vec<usize> = (0..n_buckets).map(|_| rng.range(16, 200)).collect();
        let ins: Vec<Vec<Vec<f32>>> = (0..n)
            .map(|rank| {
                shapes
                    .iter()
                    .enumerate()
                    .map(|(k, &e)| {
                        let mut r = Rng::new(seed ^ ((rank as u64 + 1) << 24) ^ (k as u64 + 1));
                        (0..e).map(|_| r.f32()).collect()
                    })
                    .collect()
            })
            .collect();
        let coll: Arc<dyn Collective> = Arc::from(by_name(spec, n).unwrap());

        let (mem_out, mem_ctr, mem_tag, mem_next) =
            run_buckets(Mesh::new(n), &coll, &ins, Wire::F16);
        let (tcp_out, tcp_ctr, tcp_tag, tcp_next) =
            run_buckets(TcpMesh::loopback(n).unwrap(), &coll, &ins, Wire::F16);

        let what = format!("{spec} n={n} buckets={shapes:?}");
        for (rank, (m, t)) in mem_out.iter().zip(&tcp_out).enumerate() {
            for (k, (mb, tb)) in m.iter().zip(t).enumerate() {
                assert_eq!(
                    bits(mb),
                    bits(tb),
                    "{what}: rank {rank} bucket {k} diverges between transports"
                );
            }
        }
        assert_eq!(mem_ctr, tcp_ctr, "{what}: traffic counters differ");
        assert_eq!(mem_tag, tcp_tag, "{what}: max tag differs");
        assert_eq!(mem_next, tcp_next, "{what}: next-tag watermark differs");
        assert_eq!(
            mem_next,
            coll.tag_span(n) * shapes.len() as u64,
            "{what}: pipeline must advance exactly one span per bucket"
        );
    }
}

#[test]
fn tcp_mesh_sums_are_exact_for_integer_payloads() {
    // Independent of the memory twin: with integer-valued operands the
    // FP32 sums are exact, so the TCP mesh must produce the closed-form
    // total on every rank — a correctness floor that doesn't assume the
    // in-memory mesh is itself right.
    for (spec, n) in [("ring", 5usize), ("torus:2x3", 6)] {
        let elems = 113usize;
        let ins: Vec<Vec<f32>> = (0..n)
            .map(|rank| (0..elems).map(|i| (rank * elems + i) as f32).collect())
            .collect();
        let coll: Arc<dyn Collective> = Arc::from(by_name(spec, n).unwrap());
        let (out, _, _) = run_schedule(TcpMesh::loopback(n).unwrap(), &coll, &ins, Wire::F32);
        for (rank, got) in out.iter().enumerate() {
            for (i, g) in got.iter().enumerate() {
                let want: f32 = (0..n).map(|r| (r * elems + i) as f32).sum();
                assert_eq!(*g, want, "{spec} n={n}: rank {rank} elem {i}");
            }
        }
    }
}
