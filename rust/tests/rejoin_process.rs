//! Kill-and-rejoin, end to end over real OS processes: a worker process is
//! SIGKILLed mid-run, a replacement `flashsgd worker --join` dials back in,
//! the coordinator admits it at the phase boundary under
//! `fault.rejoin_grace`, and the replay runs at restored full width — so
//! the final checkpoint must be **byte-identical** to an undisturbed run's.
//!
//! This is the self-healing tentpole's acceptance test. It drives the real
//! binary (`CARGO_BIN_EXE_flashsgd`), the real control socket, the real
//! join door, and polls the real `/status` HTTP endpoint to time the kill.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::process::{Child, Command, Stdio};
use std::thread;
use std::time::{Duration, Instant};

const BIN: &str = env!("CARGO_BIN_EXE_flashsgd");
const N_WORKERS: usize = 4;

/// Small but not instant: phase 1 has 24 steps, so a kill fired right
/// after `/status` first reports "running" lands mid-phase.
fn config_text(bind: &str, http: &str) -> String {
    format!(
        r#"
name = "rejoin-smoke"
arch = "tiny"
collective = "torus:2x2"
grad_wire = "fp16"
label_smoothing = 0.1
weight_decay = 5e-5
seed = 11
epochs = 2
train_size = 384
eval_every = 0
eval_batches = 2
bucket_bytes = 8192

[lr]
kind = "const"
value = 1.0
momentum = 0.9

[batch]
phases = [[0, 4, 4], [1, 8, 4]]

[transport]
mode = "tcp"
bind = "{bind}"
http = "{http}"

[fault]
enabled = true
heartbeat_interval_ms = 50
rank_timeout_ms = 10000
max_restarts = 3
rejoin_grace_ms = 20000
"#
    )
}

fn spawn_worker(join: &str) -> Child {
    Command::new(BIN)
        .args(["worker", "--join", join])
        .stdout(Stdio::null())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawning a worker process")
}

/// Minimal HTTP/1.0 GET against the coordinator's status endpoint.
fn http_get(addr: &str, path: &str) -> Option<String> {
    let mut s = TcpStream::connect(addr).ok()?;
    s.set_read_timeout(Some(Duration::from_secs(2))).ok()?;
    write!(s, "GET {path} HTTP/1.0\r\n\r\n").ok()?;
    let mut buf = String::new();
    s.read_to_string(&mut buf).ok()?;
    buf.split_once("\r\n\r\n").map(|(_, body)| body.to_string())
}

/// Run one full cluster; when `disturb` is set, kill worker 1 as soon as
/// `/status` reports the run is underway and immediately start its
/// replacement. Returns the coordinator's captured stderr.
fn run_cluster(cfg_path: &std::path::Path, ckpt: &std::path::Path, bind: &str, http: &str, disturb: bool) -> String {
    let mut coord = Command::new(BIN)
        .args([
            "coordinator",
            "--config",
            cfg_path.to_str().unwrap(),
            "--save",
            ckpt.to_str().unwrap(),
        ])
        .stdout(Stdio::null())
        .stderr(Stdio::piped())
        .spawn()
        .expect("spawning the coordinator");
    let mut stderr_pipe = coord.stderr.take().expect("piped stderr");
    let drain = thread::spawn(move || {
        let mut s = String::new();
        let _ = stderr_pipe.read_to_string(&mut s);
        s
    });

    let mut workers: Vec<Child> = (0..N_WORKERS).map(|_| spawn_worker(bind)).collect();

    if disturb {
        // Wait for the run to actually be underway before pulling the plug.
        let deadline = Instant::now() + Duration::from_secs(60);
        loop {
            assert!(Instant::now() < deadline, "run never reached the running state");
            if let Some(body) = http_get(http, "/status") {
                if body.contains(r#""state":"running""#) {
                    break;
                }
            }
            thread::sleep(Duration::from_millis(10));
        }
        thread::sleep(Duration::from_millis(50));
        workers[1].kill().expect("killing worker 1");
        let _ = workers[1].wait();
        // The replacement dials the same coordinator; the join door queues
        // it and the next phase boundary admits it within the grace.
        workers.push(spawn_worker(bind));
    }

    // Bounded wait for the coordinator; a wedged cluster must fail the
    // test, not hang CI.
    let deadline = Instant::now() + Duration::from_secs(300);
    let status = loop {
        match coord.try_wait().expect("polling the coordinator") {
            Some(st) => break st,
            None if Instant::now() > deadline => {
                let _ = coord.kill();
                for w in &mut workers {
                    let _ = w.kill();
                }
                panic!("coordinator did not finish within the deadline");
            }
            None => thread::sleep(Duration::from_millis(50)),
        }
    };
    // Workers exit on the shutdown frame (or on losing the control
    // socket); reap them, force-killing any straggler.
    for w in &mut workers {
        let reap_deadline = Instant::now() + Duration::from_secs(30);
        loop {
            match w.try_wait() {
                Ok(Some(_)) => break,
                _ if Instant::now() > reap_deadline => {
                    let _ = w.kill();
                    let _ = w.wait();
                    break;
                }
                _ => thread::sleep(Duration::from_millis(20)),
            }
        }
    }
    let stderr = drain.join().unwrap_or_default();
    assert!(
        status.success(),
        "coordinator failed (disturb={disturb}); stderr:\n{stderr}"
    );
    stderr
}

#[test]
fn killed_worker_rejoins_and_checkpoint_matches_undisturbed_run() {
    let dir = std::env::temp_dir().join(format!("flashsgd-rejoin-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();

    // Two clusters on distinct ports so a lingering socket from run A can
    // never interfere with run B.
    let (bind_a, http_a) = ("127.0.0.1:7093", "127.0.0.1:7094");
    let (bind_b, http_b) = ("127.0.0.1:7095", "127.0.0.1:7096");
    let cfg_a = dir.join("a.toml");
    let cfg_b = dir.join("b.toml");
    std::fs::write(&cfg_a, config_text(bind_a, http_a)).unwrap();
    std::fs::write(&cfg_b, config_text(bind_b, http_b)).unwrap();
    let ckpt_a = dir.join("undisturbed.ckpt");
    let ckpt_b = dir.join("disturbed.ckpt");

    let _ = run_cluster(&cfg_a, &ckpt_a, bind_a, http_a, false);
    let stderr_b = run_cluster(&cfg_b, &ckpt_b, bind_b, http_b, true);

    assert!(
        stderr_b.contains("rejoined"),
        "the replacement worker never rejoined; stderr:\n{stderr_b}"
    );
    assert!(
        stderr_b.contains("rejoin:"),
        "no rejoin re-plan was recorded; stderr:\n{stderr_b}"
    );

    let a = std::fs::read(&ckpt_a).expect("undisturbed checkpoint");
    let b = std::fs::read(&ckpt_b).expect("disturbed checkpoint");
    assert_eq!(
        a, b,
        "kill-and-rejoin changed the final checkpoint: the replay did not \
         run at restored width (or the replica invariant broke)"
    );

    let _ = std::fs::remove_dir_all(&dir);
}
