//! Fault-tolerance tests: the dead-rank deadlock fix, end to end.
//!
//! Three layers are exercised:
//!   * **transport/collectives** — kill a rank mid-collective under every
//!     algorithm and assert the survivors unwind with a typed
//!     [`MeshError`](flashsgd::collectives::MeshError) in bounded time
//!     (pre-PR: every survivor blocked forever in `recv`),
//!   * **coordinator** — a rank panic/error/hang mid-phase surfaces as a
//!     run error (fault tolerance off) or an elastic recovery (fault
//!     tolerance on): the phase replays from its boundary state on the
//!     survivors with the global batch — and the LR/momentum schedule —
//!     unchanged,
//!   * **no-churn** — with fault tolerance enabled but nothing injected,
//!     the training output is bit-identical to the subsystem being off.

use std::sync::mpsc;
use std::thread;
use std::time::{Duration, Instant};

use flashsgd::collectives::{self, Collective, Mesh, MeshError, Wire};
use flashsgd::config::{FaultConfig, InjectedFault, TrainConfig};
use flashsgd::coordinator::Trainer;
use flashsgd::sched::{BatchSchedule, LrSchedule};

/// Generous wall-clock bound for "unwinds instead of deadlocking". The
/// actual unwind is one 1 ms health tick; the slack absorbs CI scheduling.
const UNWIND_BOUND: Duration = Duration::from_secs(30);

fn base_config(name: &str, ranks: usize, steps: usize) -> TrainConfig {
    TrainConfig {
        name: name.into(),
        arch: "tiny".into(),
        collective: "torus".into(),
        grad_wire: "fp16".into(),
        label_smoothing: 0.1,
        lr: LrSchedule::Const { lr: 0.5, momentum: 0.9 },
        batch: BatchSchedule::constant(8, ranks, 8),
        weight_decay: 5e-5,
        seed: 7,
        max_steps: steps,
        eval_every: 0,
        eval_batches: 4,
        train_size: 2048,
        compute_lanes: 0,
        bucket_bytes: 8192,
        fault: FaultConfig::default(),
        transport: flashsgd::config::TransportConfig::default(),
        checkpoint: flashsgd::config::CheckpointConfig::default(),
    }
}

/// Run `coll` across `n` ranks where rank `victim` never participates:
/// it waits `delay`, then marks itself dead. Returns each survivor's
/// result and the total wall time. Pre-PR this deadlocked forever; now
/// every survivor must unwind with a `MeshError`.
fn run_with_dead_rank(
    coll: Box<dyn Collective>,
    n: usize,
    victim: usize,
    delay: Duration,
) -> (Vec<(usize, anyhow::Error)>, Duration) {
    let coll: std::sync::Arc<dyn Collective> = std::sync::Arc::from(coll);
    let eps = Mesh::new(n);
    let t0 = Instant::now();
    let (tx, rx) = mpsc::channel();
    let handles: Vec<_> = eps
        .into_iter()
        .map(|mut ep| {
            let coll = coll.clone();
            let tx = tx.clone();
            thread::spawn(move || {
                let rank = ep.rank();
                if rank == victim {
                    // Simulated death: go silent, then get declared dead
                    // (in production the monitor or a peer's deadline does
                    // the declaring; here the "corpse" flags itself).
                    thread::sleep(delay);
                    ep.mark_dead(rank);
                    return;
                }
                let mut buf: Vec<f32> = (0..256).map(|i| (rank + i) as f32).collect();
                let res = coll.all_reduce(&mut ep, &mut buf, Wire::F16, 0);
                tx.send((rank, res)).unwrap();
            })
        })
        .collect();
    drop(tx);
    let mut errs = Vec::new();
    for (rank, res) in rx {
        let err = res.expect_err(&format!(
            "rank {rank} must not complete an all-reduce missing rank {victim}"
        ));
        errs.push((rank, err));
    }
    for h in handles {
        h.join().unwrap();
    }
    (errs, t0.elapsed())
}

/// Tentpole regression, per algorithm: a dead rank mid-collective unwinds
/// every survivor with a typed `MeshError` in bounded time.
#[test]
fn dead_rank_unwinds_every_algorithm() {
    let n = 8usize;
    let cases: Vec<(&str, Box<dyn Collective>)> = vec![
        ("ring", collectives::by_name("ring", n).unwrap()),
        ("halving-doubling", collectives::by_name("halving-doubling", n).unwrap()),
        ("hierarchical:2", collectives::by_name("hierarchical:2", n).unwrap()),
        ("torus:4x2", collectives::by_name("torus:4x2", n).unwrap()),
    ];
    for (spec, coll) in cases {
        let (errs, elapsed) = run_with_dead_rank(coll, n, 3, Duration::from_millis(20));
        assert!(
            elapsed < UNWIND_BOUND,
            "{spec}: survivors took {elapsed:?} to unwind"
        );
        assert_eq!(errs.len(), n - 1, "{spec}: every survivor must error");
        for (rank, err) in errs {
            let mesh_err = err.downcast_ref::<MeshError>();
            assert!(
                mesh_err.is_some(),
                "{spec}: rank {rank} error is not a MeshError: {err:#}"
            );
            match mesh_err.unwrap() {
                MeshError::PeerDead { rank: dead } => assert_eq!(*dead, 3, "{spec}"),
                MeshError::Aborted { origin } => assert_eq!(*origin, 3, "{spec}"),
                other => panic!("{spec}: rank {rank} got unexpected {other:?}"),
            }
        }
    }
}

/// Same regression through the bucketed pipeline schedule (many tag
/// windows in flight): survivors unwind mid-bucket, cleanly.
#[test]
fn dead_rank_unwinds_bucketed_schedule() {
    let n = 4usize;
    let coll: std::sync::Arc<dyn Collective> =
        std::sync::Arc::from(collectives::by_name("torus:2x2", n).unwrap());
    let eps = Mesh::new(n);
    let t0 = Instant::now();
    let handles: Vec<_> = eps
        .into_iter()
        .map(|mut ep| {
            let coll = coll.clone();
            thread::spawn(move || -> (usize, anyhow::Result<u64>) {
                let rank = ep.rank();
                if rank == 1 {
                    thread::sleep(Duration::from_millis(20));
                    ep.mark_dead(rank);
                    return (rank, Ok(0));
                }
                let mut bufs: Vec<Vec<f32>> =
                    (0..6).map(|b| vec![(rank * 10 + b) as f32; 64]).collect();
                let res =
                    collectives::bucketed::all_reduce_buckets(&*coll, &mut ep, &mut bufs, Wire::F16, 0);
                (rank, res)
            })
        })
        .collect();
    for h in handles {
        let (rank, res) = h.join().unwrap();
        if rank != 1 {
            let err = res.expect_err("survivor must unwind");
            assert!(
                err.downcast_ref::<MeshError>().is_some(),
                "rank {rank}: {err:#}"
            );
        }
    }
    assert!(t0.elapsed() < UNWIND_BOUND);
}

/// Satellite 3: a prime worker count under the auto `"torus"` spec routes
/// to the flat ring — same object, same wire behaviour — instead of a
/// degenerate 7x1 torus paying phase overhead for nothing.
#[test]
fn prime_torus_routes_to_ring_on_the_wire() {
    let n = 7usize;
    let auto = collectives::by_name("torus", n).unwrap();
    assert_eq!(auto.name(), "ring", "prime auto-torus must be the real ring");
    assert_eq!(auto.p2p_steps(n), collectives::RingAllReduce.p2p_steps(n));
    assert_eq!(auto.tag_span(n), collectives::RingAllReduce.tag_span(n));

    // On the wire: identical results and identical traffic counters.
    let run = |coll: std::sync::Arc<dyn Collective>| {
        let eps = Mesh::new(n);
        let counters = eps[0].counters_arc();
        let handles: Vec<_> = eps
            .into_iter()
            .map(|mut ep| {
                let coll = coll.clone();
                thread::spawn(move || {
                    let rank = ep.rank();
                    let mut buf: Vec<f32> =
                        (0..210).map(|i| ((rank * 31 + i) % 17) as f32).collect();
                    coll.all_reduce(&mut ep, &mut buf, Wire::F32, 0).unwrap();
                    buf
                })
            })
            .collect();
        let results: Vec<Vec<f32>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        (results, counters.snapshot())
    };
    let (r_auto, c_auto) = run(std::sync::Arc::from(auto));
    let (r_ring, c_ring) = run(std::sync::Arc::new(collectives::RingAllReduce));
    assert_eq!(r_auto, r_ring, "auto-torus(7) and ring must agree bitwise");
    assert_eq!(c_auto, c_ring, "auto-torus(7) and ring must move identical bytes");

    // Recovery re-planning uses the same rule, even from a fixed spec.
    let elastic = collectives::by_name_elastic("torus:4x2", 7, true).unwrap();
    assert_eq!(elastic.name(), "ring");
    let elastic = collectives::by_name_elastic("torus:4x2", 6, true).unwrap();
    assert_eq!(elastic.name(), "torus2d(3x2)");
    let elastic = collectives::by_name_elastic("hierarchical:4", 6, true).unwrap();
    assert_eq!(elastic.name(), "torus2d(3x2)");
    // not degraded -> misfit specs still fail loudly
    assert!(collectives::by_name_elastic("torus:4x2", 7, false).is_err());
    assert!(collectives::by_name_elastic("hierarchical:4", 6, false).is_err());
}

/// Satellite 1 regression: with fault tolerance *off*, a rank panicking
/// mid-phase surfaces as a run error in bounded time (pre-PR the other
/// ranks blocked forever in their next collective and `run()` never
/// returned).
#[test]
fn rank_panic_surfaces_as_error_in_bounded_time() {
    let mut cfg = base_config("ft-panic", 4, 8);
    cfg.fault = FaultConfig {
        inject: Some(InjectedFault::panic_at(2, 3)),
        ..FaultConfig::disabled()
    };
    let t0 = Instant::now();
    let err = Trainer::new(cfg).unwrap().run().unwrap_err();
    assert!(
        t0.elapsed() < UNWIND_BOUND,
        "run took {:?} to fail",
        t0.elapsed()
    );
    let msg = format!("{err:#}");
    assert!(
        msg.contains("rank 2 panicked"),
        "error must name the panicking rank: {msg}"
    );
}

/// The tentpole, end to end: rank 2 dies mid-phase, the coordinator
/// detects it, re-plans the phase on the survivors (4 workers × batch 8 →
/// 2 workers × batch 16: global batch preserved, so the step count and
/// schedule are untouched) and the run completes with the recovery on
/// record and all survivors still bit-identical.
#[test]
fn mid_phase_death_recovers_on_survivors() {
    let mut cfg = base_config("ft-recover", 4, 12);
    cfg.fault.inject = Some(InjectedFault::error_at(2, 6));
    let report = Trainer::new(cfg).unwrap().run().unwrap();

    assert_eq!(report.summary.steps, 12, "recovery must not lose steps");
    assert_eq!(report.recoveries.len(), 1);
    let r = &report.recoveries[0];
    assert_eq!(r.dead_ranks, vec![2]);
    assert_eq!(r.workers_before, 4);
    // global batch 32 on ≤3 survivors: 3 ∤ 32, so 2 workers × 16.
    assert_eq!(r.workers_after, 2);
    assert_eq!(r.per_worker_after, 16);
    assert!(report.summary.last_loss.is_finite());
    // the schedule was preserved: per-step global batch never changed
    assert!(report.metrics.steps.iter().all(|s| s.global_batch == 32));
}

/// Hang detection: a rank going *silent* (no error, no panic) is declared
/// dead by the heartbeat monitor once its beat goes `rank_timeout` stale,
/// and the run still recovers. This is the failure mode fast error
/// propagation cannot catch.
#[test]
fn hung_rank_is_detected_and_recovered() {
    let mut cfg = base_config("ft-hang", 4, 10);
    cfg.fault.heartbeat_interval = Duration::from_millis(50);
    cfg.fault.rank_timeout = Duration::from_millis(1500);
    cfg.fault.inject = Some(InjectedFault::hang_at(1, 4, 5000));
    let t0 = Instant::now();
    let report = Trainer::new(cfg).unwrap().run().unwrap();
    assert_eq!(report.summary.steps, 10);
    assert_eq!(report.recoveries.len(), 1);
    assert_eq!(report.recoveries[0].dead_ranks, vec![1]);
    // detection at ~1.5 s + joining the 5 s sleeper bounds the wall time;
    // the point is it terminates promptly, not after some giant timeout.
    assert!(t0.elapsed() < Duration::from_secs(60));
}

/// Exhausted restart budget: a rank that dies on every attempt turns the
/// death fatal once `max_restarts` is spent, with the budget named in the
/// error.
#[test]
fn max_restarts_exhaustion_is_fatal() {
    let mut cfg = base_config("ft-budget", 4, 8);
    cfg.fault.max_restarts = 1;
    // fires on attempts 0 and 1: the retry dies too (rank 0 survives both
    // plans, so the injection target exists on the degraded world as well)
    cfg.fault.inject = Some(InjectedFault {
        attempts: 2,
        ..InjectedFault::error_at(0, 3)
    });
    let err = Trainer::new(cfg).unwrap().run().unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("max_restarts"),
        "error must name the exhausted budget: {msg}"
    );
}

/// Numeric health guard: a NaN loss is *deterministic* — the FP32 loss
/// reduction hands every rank the same poisoned value, and a phase replay
/// would reproduce it exactly — so the coordinator must fail the run
/// immediately, naming rank and step, instead of spending restart budget
/// on it. The injection fires on attempt 0 only: if the coordinator
/// wrongly burned a restart, the replay would *succeed* and `run()`
/// would return `Ok` — so the `unwrap_err` below is itself the proof
/// that no restart was consumed.
#[test]
fn nan_loss_trips_health_guard_without_burning_restarts() {
    let mut cfg = base_config("ft-nan", 4, 8);
    // Fault tolerance ON with budget to spare: the guard must still
    // refuse to retry a deterministic failure.
    cfg.fault.max_restarts = 3;
    cfg.fault.inject = Some(InjectedFault::nan_at(1, 4));
    let t0 = Instant::now();
    let err = Trainer::new(cfg).unwrap().run().unwrap_err();
    assert!(
        t0.elapsed() < UNWIND_BOUND,
        "guard took {:?} to fail the run",
        t0.elapsed()
    );
    let msg = format!("{err:#}");
    assert!(
        msg.contains("non-finite step loss"),
        "error must name the broken quantity: {msg}"
    );
    // Every rank raises in lockstep (the reduction made the NaN global);
    // whichever report surfaces must name its rank and the exact step.
    assert!(
        msg.contains("at rank") && msg.contains("step 4"),
        "error must name rank and step: {msg}"
    );
    assert!(
        msg.contains("numeric health guard tripped"),
        "the deterministic-failure gate must fire, not the recovery ladder: {msg}"
    );
}

/// No-churn guarantee: with nothing injected, fault tolerance enabled vs
/// fully disabled produces bit-identical training output — the detection
/// machinery (heartbeats, bounded-tick recv, monitor thread) must not
/// perturb numerics anywhere.
#[test]
fn fault_tolerance_no_churn_is_bit_identical() {
    let dir = std::env::temp_dir().join(format!("fsgd-ft-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let run = |name: &str, fault: FaultConfig| {
        let mut cfg = base_config(name, 4, 10);
        cfg.fault = fault;
        let ckpt = dir.join(format!("{name}.ckpt"));
        let report = Trainer::new(cfg)
            .unwrap()
            .with_checkpoint(&ckpt)
            .run()
            .unwrap();
        (report, std::fs::read(&ckpt).unwrap())
    };
    let (rep_on, bytes_on) = run("ft-on", FaultConfig::default());
    let (rep_off, bytes_off) = run("ft-off", FaultConfig::disabled());
    assert_eq!(
        bytes_on, bytes_off,
        "fault tolerance must be a zero-numerics-impact feature"
    );
    assert_eq!(rep_on.summary.steps, rep_off.summary.steps);
    assert_eq!(
        rep_on.summary.last_loss.to_bits(),
        rep_off.summary.last_loss.to_bits()
    );
    assert!(rep_on.recoveries.is_empty() && rep_off.recoveries.is_empty());
    std::fs::remove_dir_all(&dir).ok();
}

/// Satellite 2: resuming a checkpoint under a different batch schedule is
/// caught by the samples cross-check instead of silently desyncing the
/// data stream.
#[test]
fn checkpoint_resume_rejects_mismatched_schedule() {
    let dir = std::env::temp_dir().join(format!("fsgd-ftck-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let ckpt = dir.join("mid.ckpt");
    // train 4 steps at global batch 32 -> checkpoint says 128 samples
    Trainer::new(base_config("ft-ck-save", 4, 4))
        .unwrap()
        .with_checkpoint(&ckpt)
        .run()
        .unwrap();
    // resume under a *doubled* per-worker batch: step 4 now means 256
    // samples — the resume must bail, not continue on the wrong stream
    let mut cfg = base_config("ft-ck-bad", 4, 8);
    cfg.batch = BatchSchedule::constant(16, 4, 8);
    let err = Trainer::new(cfg)
        .unwrap()
        .with_resume(&ckpt)
        .run()
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("checkpoint mismatch"),
        "must flag the schedule mismatch: {msg}"
    );
    // sanity: the unchanged schedule still resumes fine
    let report = Trainer::new(base_config("ft-ck-good", 4, 8))
        .unwrap()
        .with_resume(&ckpt)
        .run()
        .unwrap();
    assert_eq!(report.summary.steps, 4); // the remaining 4 of 8
    std::fs::remove_dir_all(&dir).ok();
}
