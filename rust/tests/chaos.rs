//! Chaos conformance: every collective schedule must be **bit-identical**
//! under the seeded network-chaos harness, over real loopback TCP sockets.
//!
//! The harness ([`ChaosTransport`]) injects per-frame delay,
//! loss-as-latency, duplication and reordering, all derived purely from
//! `(seed, src, dst, tag)`; [`LinkPolicy`] adds TCP-level connection
//! resets healed by the transport's seq-fenced reconnect path. None of it
//! may change a single ULP of any rank's result — the schedules fix the
//! reduction order, and the transport either absorbs the injected event
//! or declares a rank dead (which these tests assert never happens).
//!
//! Checked per case:
//!   * **results** — chaotic run ≡ clean run, bit for bit, on every rank;
//!   * **tags** — same `max_tag_seen` watermark (chaos must not leak into
//!     the tag layout);
//!   * **conservation** — within the chaotic run, logical bytes sent ==
//!     received (duplicates are consumed, never silently parked);
//!   * **determinism** — re-running the same seed injects the exact same
//!     event tallies;
//!   * **off-switch** — a disabled config is a strict passthrough: equal
//!     results *and* equal traffic counters, zero injections.

use std::sync::Arc;
use std::thread;
use std::time::Duration;

use flashsgd::collectives::bucketed::all_reduce_buckets;
use flashsgd::collectives::{
    by_name, BackoffConfig, ChaosConfig, ChaosCounters, ChaosTransport, Collective, LinkPolicy,
    TcpEndpoint, TcpMesh, TcpOptions, Transport, Wire,
};

struct Rng(u64);

impl Rng {
    fn new(seed: u64) -> Self {
        Rng(seed.wrapping_mul(2685821657736338717).max(1))
    }

    fn next(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.0 = x;
        x.wrapping_mul(2685821657736338717)
    }

    /// Small, FP16-exact magnitudes (see transport_conformance.rs).
    fn f32(&mut self) -> f32 {
        let q = (self.next() % 513) as f32 - 256.0;
        q * 0.03125
    }
}

fn inputs(seed: u64, n: usize, elems: usize) -> Vec<Vec<f32>> {
    (0..n)
        .map(|rank| {
            let mut rng = Rng::new(seed ^ ((rank as u64 + 1) << 32));
            (0..elems).map(|_| rng.f32()).collect()
        })
        .collect()
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// A seed with every injection mode active. Rates are high enough that a
/// few hundred frames always trip each one, low enough that the injected
/// sleeps stay far below a second per case.
fn noisy(seed: u64) -> ChaosConfig {
    ChaosConfig {
        enabled: true,
        seed,
        delay_prob: 0.25,
        delay_us_max: 200,
        drop_prob: 0.15,
        drop_delay_us: 500,
        dup_prob: 0.2,
        reorder_prob: 0.25,
        slow_prob: 0.0,
        slow_factor: 4.0,
    }
}

/// Drive `coll` once over the given endpoints, one thread per rank.
fn run_schedule<T: Transport + Send + 'static>(
    eps: Vec<T>,
    coll: &Arc<dyn Collective>,
    ins: &[Vec<f32>],
    wire: Wire,
) -> (Vec<Vec<f32>>, (u64, u64, u64), u64) {
    let counters = eps[0].counters_arc();
    let handles: Vec<_> = eps
        .into_iter()
        .map(|mut ep| {
            let coll = coll.clone();
            let mut buf = ins[ep.rank()].clone();
            thread::spawn(move || {
                coll.all_reduce(&mut ep, &mut buf, wire, 0).unwrap();
                assert_eq!(ep.pending_messages(), 0, "rank {}: residue", ep.rank());
                buf
            })
        })
        .collect();
    let results: Vec<Vec<f32>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    (results, counters.snapshot(), counters.max_tag_seen())
}

fn chaotic_mesh(
    n: usize,
    cfg: &ChaosConfig,
) -> (Vec<ChaosTransport<TcpEndpoint>>, Arc<ChaosCounters>) {
    ChaosTransport::wrap_all(TcpMesh::loopback(n).unwrap(), cfg)
}

/// Every schedule family under the full noisy seed: the chaotic TCP run
/// must match the clean TCP run bit for bit.
#[test]
fn every_schedule_is_bit_identical_under_chaos() {
    let cases = [
        ("ring", 4usize, Wire::F32),
        ("halving-doubling", 4, Wire::F16),
        ("hierarchical:2", 4, Wire::F32),
        ("torus:2x2", 4, Wire::F16),
    ];
    for (ci, (spec, n, wire)) in cases.into_iter().enumerate() {
        let seed = 0xC4A0_0001 + ci as u64 * 131;
        let elems = 257usize; // awkward residue vs every world size
        let ins = inputs(seed, n, elems);
        let coll: Arc<dyn Collective> = Arc::from(by_name(spec, n).unwrap());

        let (clean_out, clean_ctr, clean_tag) =
            run_schedule(TcpMesh::loopback(n).unwrap(), &coll, &ins, wire);
        let (eps, chaos_ctr) = chaotic_mesh(n, &noisy(seed));
        let (chaos_out, chaos_traffic, chaos_tag) = run_schedule(eps, &coll, &ins, wire);

        let what = format!("{spec} n={n} wire={wire:?}");
        for (rank, (c, h)) in clean_out.iter().zip(&chaos_out).enumerate() {
            assert_eq!(bits(c), bits(h), "{what}: rank {rank} diverges under chaos");
        }
        assert_eq!(clean_tag, chaos_tag, "{what}: tag watermark moved under chaos");
        // Duplicates inflate traffic, but conservation must hold: every
        // logical byte sent (originals + dups) is received and accounted.
        let (sent, rcvd, _) = chaos_traffic;
        assert_eq!(sent, rcvd, "{what}: chaotic run leaks bytes");
        assert!(
            sent >= clean_ctr.0,
            "{what}: chaos cannot shrink traffic ({sent} < {})",
            clean_ctr.0
        );
        assert!(chaos_ctr.total() > 0, "{what}: noisy seed injected nothing");
    }
}

/// The bucketed streaming pipeline — the data path of an overlapped
/// training step — under the same noisy seed.
#[test]
fn bucketed_pipeline_is_bit_identical_under_chaos() {
    let n = 4usize;
    let seed = 0xC4A0_B0C4u64;
    let shapes = [96usize, 33, 160];
    let ins: Vec<Vec<Vec<f32>>> = (0..n)
        .map(|rank| {
            shapes
                .iter()
                .enumerate()
                .map(|(k, &e)| {
                    let mut r = Rng::new(seed ^ ((rank as u64 + 1) << 24) ^ (k as u64 + 1));
                    (0..e).map(|_| r.f32()).collect()
                })
                .collect()
        })
        .collect();

    let run = |eps: Vec<Box<dyn Transport>>| -> (Vec<Vec<Vec<f32>>>, u64) {
        let coll: Arc<dyn Collective> = Arc::from(by_name("ring", n).unwrap());
        let handles: Vec<_> = eps
            .into_iter()
            .map(|mut ep| {
                let coll = coll.clone();
                let mut bufs = ins[ep.rank()].clone();
                thread::spawn(move || {
                    let next =
                        all_reduce_buckets(&*coll, &mut *ep, &mut bufs, Wire::F16, 0).unwrap();
                    (bufs, next)
                })
            })
            .collect();
        let joined: Vec<(Vec<Vec<f32>>, u64)> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        let next = joined[0].1;
        (joined.into_iter().map(|(b, _)| b).collect(), next)
    };

    let clean: Vec<Box<dyn Transport>> = TcpMesh::loopback(n)
        .unwrap()
        .into_iter()
        .map(|e| Box::new(e) as Box<dyn Transport>)
        .collect();
    let (clean_out, clean_next) = run(clean);
    let (eps, chaos_ctr) = chaotic_mesh(n, &noisy(seed));
    let (chaos_out, chaos_next) = run(
        eps.into_iter()
            .map(|e| Box::new(e) as Box<dyn Transport>)
            .collect(),
    );

    for (rank, (c, h)) in clean_out.iter().zip(&chaos_out).enumerate() {
        for (k, (cb, hb)) in c.iter().zip(h).enumerate() {
            assert_eq!(bits(cb), bits(hb), "rank {rank} bucket {k} diverges under chaos");
        }
    }
    assert_eq!(clean_next, chaos_next, "next-tag watermark moved under chaos");
    assert!(chaos_ctr.total() > 0, "noisy seed injected nothing");
}

/// A seed-elected slow rank only stretches injected sleeps — a
/// heterogeneous cluster must still produce bit-identical reductions
/// (straggling changes *when*, never *what*).
#[test]
fn slow_ranks_are_bit_identical_to_a_clean_run() {
    let n = 4usize;
    let seed = 0xC4A0_510Au64;
    let ins = inputs(seed, n, 257);
    let coll: Arc<dyn Collective> = Arc::from(by_name("halving-doubling", n).unwrap());
    let (clean_out, _, clean_tag) =
        run_schedule(TcpMesh::loopback(n).unwrap(), &coll, &ins, Wire::F16);
    let mut cfg = noisy(seed);
    cfg.slow_prob = 1.0; // every rank elected slow — worst case
    cfg.slow_factor = 3.0;
    let (eps, chaos_ctr) = chaotic_mesh(n, &cfg);
    let (slow_out, _, slow_tag) = run_schedule(eps, &coll, &ins, Wire::F16);
    for (rank, (c, s)) in clean_out.iter().zip(&slow_out).enumerate() {
        assert_eq!(bits(c), bits(s), "rank {rank} diverges under slowdown");
    }
    assert_eq!(clean_tag, slow_tag, "tag watermark moved under slowdown");
    assert!(chaos_ctr.total() > 0, "noisy seed injected nothing");
}

/// Same seed, same schedule → the exact same injected-event tallies. The
/// whole point of a *deterministic* chaos harness is that a failure found
/// under a seed reproduces under that seed.
#[test]
fn chaos_schedule_is_deterministic_per_seed() {
    let n = 4usize;
    let ins = inputs(0xD37E_2141, n, 128);
    let coll: Arc<dyn Collective> = Arc::from(by_name("ring", n).unwrap());
    let mut snaps = Vec::new();
    for _ in 0..2 {
        let (eps, ctr) = chaotic_mesh(n, &noisy(0xD37E_2141));
        let _ = run_schedule(eps, &coll, &ins, Wire::F32);
        snaps.push(ctr.snapshot());
    }
    assert_eq!(snaps[0], snaps[1], "same seed must inject the same events");
    assert!(snaps[0].0 + snaps[0].1 + snaps[0].2 + snaps[0].3 > 0);
}

/// `enabled = false` is a strict passthrough: identical results, identical
/// traffic counters, zero injections — the acceptance bar for leaving the
/// harness compiled into the production transport path.
#[test]
fn disabled_chaos_is_a_passthrough() {
    let n = 4usize;
    let ins = inputs(0x0FF5_EED5, n, 200);
    let coll: Arc<dyn Collective> = Arc::from(by_name("torus:2x2", n).unwrap());
    let (clean_out, clean_ctr, clean_tag) =
        run_schedule(TcpMesh::loopback(n).unwrap(), &coll, &ins, Wire::F16);
    let off = ChaosConfig { enabled: false, ..noisy(0x0FF5_EED5) };
    let (eps, chaos_ctr) = chaotic_mesh(n, &off);
    let (off_out, off_ctr, off_tag) = run_schedule(eps, &coll, &ins, Wire::F16);
    for (rank, (c, o)) in clean_out.iter().zip(&off_out).enumerate() {
        assert_eq!(bits(c), bits(o), "rank {rank} diverges with chaos disabled");
    }
    assert_eq!(clean_ctr, off_ctr, "disabled chaos altered traffic");
    assert_eq!(clean_tag, off_tag);
    assert_eq!(chaos_ctr.total(), 0, "disabled chaos injected events");
}

/// TCP-level chaos: a [`LinkPolicy`]-injected connection reset mid-
/// collective must heal through the seq-fenced reconnect path with no
/// lost or duplicated frames — same bits as the clean run, no deaths.
#[test]
fn injected_reset_heals_mid_collective_bit_identically() {
    let n = 4usize;
    let ins = inputs(0x2E5E_7001, n, 300);
    let coll: Arc<dyn Collective> = Arc::from(by_name("ring", n).unwrap());
    let (clean_out, _, _) = run_schedule(TcpMesh::loopback(n).unwrap(), &coll, &ins, Wire::F32);

    // Cut the 0→1 connection just before rank 0's third payload frame on
    // that link — mid-reduce-scatter for a ring of 4.
    let policy = Arc::new(LinkPolicy::default().with_reset(0, 1, 2));
    let opts = TcpOptions {
        reconnect_attempts: 3,
        backoff: BackoffConfig {
            base: Duration::from_millis(10),
            max: Duration::from_millis(100),
            attempts: 10,
            jitter: 0.0,
        },
        link_policy: Some(policy.clone()),
        ..TcpOptions::default()
    };
    let eps = TcpMesh::loopback_opts(n, opts).unwrap();
    let counters = eps[0].counters_arc();
    let health = eps[0].health_arc();
    let (healed_out, (sent, rcvd, _), _) = run_schedule(eps, &coll, &ins, Wire::F32);

    for (rank, (c, h)) in clean_out.iter().zip(&healed_out).enumerate() {
        assert_eq!(bits(c), bits(h), "rank {rank} diverges across a healed reset");
    }
    assert_eq!(sent, rcvd, "healed run leaks bytes");
    assert_eq!(policy.snapshot().0, 1, "the reset must fire exactly once");
    assert!(counters.reconnects_seen() >= 1, "the heal path never ran");
    assert!(health.first_dead().is_none(), "a healed reset must not kill a rank");
}
