//! Integration tests: the full Trainer stack over real PJRT artifacts.
//!
//! These need `make artifacts` to have produced `artifacts/manifest.json`;
//! when artifacts are missing every test skips with a notice (so `cargo
//! test` stays usable in a fresh checkout).

use flashsgd::config::TrainConfig;
use flashsgd::coordinator::Trainer;
use flashsgd::sched::{BatchSchedule, LrSchedule, Phase};

const ARTIFACTS: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");

fn have_artifacts() -> bool {
    std::path::Path::new(ARTIFACTS).join("manifest.json").exists()
}

fn base_config(name: &str, ranks: usize, steps: usize) -> TrainConfig {
    TrainConfig {
        name: name.into(),
        arch: "tiny".into(),
        collective: "torus".into(),
        grad_wire: "fp16".into(),
        label_smoothing: 0.1,
        lr: LrSchedule::Const { lr: 4.0, momentum: 0.9 },
        batch: BatchSchedule::constant(8, ranks, 8),
        weight_decay: 5e-5,
        seed: 7,
        max_steps: steps,
        eval_every: 0,
        eval_batches: 4,
        train_size: 2048,
    }
}

#[test]
fn quickstart_reduces_loss() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let report = Trainer::new(base_config("it-quickstart", 4, 25), ARTIFACTS)
        .unwrap()
        .run()
        .unwrap();
    let s = &report.summary;
    assert_eq!(s.steps, 25);
    assert!(s.first_loss.is_finite() && s.last_loss.is_finite());
    assert!(
        s.last_loss < s.first_loss,
        "loss {:.4} -> {:.4}",
        s.first_loss,
        s.last_loss
    );
    // loss starts near ln(10) + smoothing offset for 10 classes
    assert!(s.first_loss > 1.5 && s.first_loss < 4.0, "{}", s.first_loss);
}

#[test]
fn batch_size_control_swaps_executables_mid_run() {
    if !have_artifacts() {
        return;
    }
    let mut config = base_config("it-bsc", 4, 0);
    // 2048 samples, 8x4=32/step -> 64 steps/epoch; switch at epoch 1.
    config.batch = BatchSchedule::new(
        vec![
            Phase { from_epoch: 0, per_worker: 8, workers: 4 },
            Phase { from_epoch: 1, per_worker: 16, workers: 4 },
        ],
        2,
    );
    let report = Trainer::new(config, ARTIFACTS).unwrap().run().unwrap();
    let batches: Vec<usize> = report.metrics.steps.iter().map(|s| s.global_batch).collect();
    assert!(batches.contains(&32), "phase 1 batches: {batches:?}");
    assert!(batches.contains(&64), "phase 2 missing: {batches:?}");
    // the switch happens exactly once, at the epoch boundary
    let switches = batches.windows(2).filter(|w| w[0] != w[1]).count();
    assert_eq!(switches, 1, "{batches:?}");
    // training continued sanely across the swap
    assert!(report.summary.last_loss.is_finite());
    assert!(report.summary.last_loss < report.summary.first_loss);
}

#[test]
fn collective_choice_does_not_change_numerics_much() {
    if !have_artifacts() {
        return;
    }
    let run = |spec: &str| {
        let mut c = base_config("it-coll", 4, 12);
        c.collective = spec.into();
        c.grad_wire = "fp32".into();
        Trainer::new(c, ARTIFACTS).unwrap().run().unwrap()
    };
    let torus = run("torus:2x2");
    let ring = run("ring");
    let hier = run("hierarchical:2");
    // identical data/seed; only reduction order differs (fp32 rounding)
    let t0 = torus.metrics.steps[0].loss;
    assert!((t0 - ring.metrics.steps[0].loss).abs() < 1e-5);
    assert!((t0 - hier.metrics.steps[0].loss).abs() < 1e-5);
    let tl = torus.summary.last_loss;
    assert!((tl - ring.summary.last_loss).abs() < 2e-2, "{tl} vs {}", ring.summary.last_loss);
    assert!((tl - hier.summary.last_loss).abs() < 2e-2);
}

#[test]
fn fp16_wire_tracks_fp32_training() {
    if !have_artifacts() {
        return;
    }
    let run = |wire: &str| {
        let mut c = base_config("it-wire", 4, 12);
        c.grad_wire = wire.into();
        Trainer::new(c, ARTIFACTS).unwrap().run().unwrap()
    };
    let h = run("fp16");
    let f = run("fp32");
    // same trajectory within fp16 quantisation noise
    assert!(
        (h.summary.last_loss - f.summary.last_loss).abs() < 5e-2,
        "fp16 {:.4} vs fp32 {:.4}",
        h.summary.last_loss,
        f.summary.last_loss
    );
}

#[test]
fn eval_beats_chance_after_training() {
    if !have_artifacts() {
        return;
    }
    let mut config = base_config("it-eval", 4, 60);
    config.eval_batches = 8;
    let report = Trainer::new(config, ARTIFACTS).unwrap().run().unwrap();
    let acc = report.final_eval.expect("final eval").accuracy;
    // 10 classes: chance = 10%; the synthetic task is easy
    assert!(acc > 0.15, "top-1 {:.1}% not above chance", acc * 100.0);
}

#[test]
fn invalid_grid_is_a_clean_error() {
    if !have_artifacts() {
        return;
    }
    let mut config = base_config("it-badgrid", 4, 5);
    config.collective = "torus:3x3".into(); // 9 != 4 ranks
    let err = Trainer::new(config, ARTIFACTS).unwrap().run().unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("torus"), "unexpected error: {msg}");
}

#[test]
fn unknown_arch_fails_at_construction() {
    if !have_artifacts() {
        return;
    }
    let mut config = base_config("it-badarch", 2, 2);
    config.arch = "resnet9000".into();
    assert!(Trainer::new(config, ARTIFACTS).is_err());
}

#[test]
fn single_rank_degenerate_case_works() {
    if !have_artifacts() {
        return;
    }
    let mut config = base_config("it-1rank", 1, 8);
    config.collective = "torus:1x1".into();
    let report = Trainer::new(config, ARTIFACTS).unwrap().run().unwrap();
    assert_eq!(report.summary.steps, 8);
    assert!(report.summary.last_loss.is_finite());
}

#[test]
fn determinism_same_seed_same_curve() {
    if !have_artifacts() {
        return;
    }
    let run = || {
        Trainer::new(base_config("it-det", 4, 8), ARTIFACTS)
            .unwrap()
            .run()
            .unwrap()
            .metrics
            .steps
            .iter()
            .map(|s| s.loss)
            .collect::<Vec<f64>>()
    };
    let a = run();
    let b = run();
    assert_eq!(a, b, "same seed must give a bit-identical loss curve");
}

#[test]
fn checkpoint_resume_is_exactly_continuous() {
    if !have_artifacts() {
        return;
    }
    let dir = std::env::temp_dir().join(format!("fsgd-it-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let ckpt = dir.join("mid.ckpt");

    // Continuous 16-step run.
    let continuous = Trainer::new(base_config("it-cont", 4, 16), ARTIFACTS)
        .unwrap()
        .run()
        .unwrap();

    // 8 steps + save, then resume for the remaining 8.
    Trainer::new(base_config("it-part1", 4, 8), ARTIFACTS)
        .unwrap()
        .with_checkpoint(&ckpt)
        .run()
        .unwrap();
    let resumed = Trainer::new(base_config("it-part2", 4, 16), ARTIFACTS)
        .unwrap()
        .with_resume(&ckpt)
        .run()
        .unwrap();

    // The resumed run must reproduce steps 8..16 bit-for-bit.
    let cont_tail: Vec<(usize, f64)> = continuous
        .metrics
        .steps
        .iter()
        .skip(8)
        .map(|s| (s.step, s.loss))
        .collect();
    let res_all: Vec<(usize, f64)> = resumed
        .metrics
        .steps
        .iter()
        .map(|s| (s.step, s.loss))
        .collect();
    assert_eq!(res_all.len(), 8);
    assert_eq!(cont_tail, res_all);

    // resuming past the end is a clean error
    let done = dir.join("done.ckpt");
    Trainer::new(base_config("it-done", 4, 16), ARTIFACTS)
        .unwrap()
        .with_checkpoint(&done)
        .run()
        .unwrap();
    let err = Trainer::new(base_config("it-past", 4, 16), ARTIFACTS)
        .unwrap()
        .with_resume(&done)
        .run()
        .unwrap_err();
    assert!(format!("{err:#}").contains("end of this schedule"));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn halving_doubling_collective_trains_too() {
    if !have_artifacts() {
        return;
    }
    let mut config = base_config("it-hd", 4, 10);
    config.collective = "halving-doubling".into();
    let report = Trainer::new(config, ARTIFACTS).unwrap().run().unwrap();
    assert!(report.summary.last_loss < report.summary.first_loss);
}

#[test]
fn config_b_momentum_applied_from_schedule() {
    if !have_artifacts() {
        return;
    }
    let mut config = base_config("it-cfgb", 4, 6);
    config.lr = LrSchedule::ConfigB {
        warmup_epochs: 1.0,
        warmup_start: 0.1,
        base_low: 1.0,
        base_high: 2.0,
        switch_epoch: 3.0,
        total_epochs: 8.0,
    };
    let report = Trainer::new(config, ARTIFACTS).unwrap().run().unwrap();
    // global batch 32 << 32K reference -> momentum clamps to 0.0
    for s in &report.metrics.steps {
        assert_eq!(s.momentum, 0.0);
        assert!(s.lr > 0.0 && s.lr < 1.0);
    }
}
