//! Integration tests: the full Trainer stack, end-to-end, on the pure-Rust
//! [`ReferenceBackend`](flashsgd::runtime::ReferenceBackend).
//!
//! No Python, no artifacts, no XLA — a clean `cargo test` exercises the
//! whole coordination layer the paper is about: multi-rank 2D-torus
//! all-reduce, batch-size-control phase swaps, the FP16 gradient wire with
//! the FP32 BN/loss wire, LARS, and checkpoint/resume.

use flashsgd::config::TrainConfig;
use flashsgd::coordinator::Trainer;
use flashsgd::sched::{BatchSchedule, LrSchedule, Phase};

fn base_config(name: &str, ranks: usize, steps: usize) -> TrainConfig {
    TrainConfig {
        name: name.into(),
        arch: "tiny".into(),
        collective: "torus".into(),
        grad_wire: "fp16".into(),
        label_smoothing: 0.1,
        lr: LrSchedule::Const { lr: 0.5, momentum: 0.9 },
        batch: BatchSchedule::constant(8, ranks, 8),
        weight_decay: 5e-5,
        seed: 7,
        max_steps: steps,
        eval_every: 0,
        eval_batches: 4,
        train_size: 2048,
        compute_lanes: 0,
        // Default-on: the whole suite exercises the backward-overlapped
        // bucketed pipeline; dedicated tests below pin bucket_bytes = 0
        // (the serial single-bucket schedule) against it.
        bucket_bytes: 8192,
        fault: flashsgd::config::FaultConfig::default(),
        transport: flashsgd::config::TransportConfig::default(),
        checkpoint: flashsgd::config::CheckpointConfig::default(),
    }
}

#[test]
fn quickstart_reduces_loss() {
    let report = Trainer::new(base_config("it-quickstart", 4, 30))
        .unwrap()
        .run()
        .unwrap();
    let s = &report.summary;
    assert_eq!(s.steps, 30);
    assert!(s.first_loss.is_finite() && s.last_loss.is_finite());
    assert!(
        s.last_loss < s.first_loss,
        "loss {:.4} -> {:.4}",
        s.first_loss,
        s.last_loss
    );
    // loss starts near ln(10) for 10 classes
    assert!(s.first_loss > 1.5 && s.first_loss < 4.0, "{}", s.first_loss);
}

/// The headline end-to-end guarantee: a 2-phase batch-size schedule on a
/// 2×2 torus over 4 rank threads, FP16 gradient wire — and every rank
/// finishes every phase with bit-identical parameters, momenta and BN
/// state (the coordinator aborts the run otherwise).
#[test]
fn two_phase_torus_run_keeps_all_ranks_bit_identical() {
    let mut config = base_config("it-2phase-torus", 4, 0);
    config.collective = "torus:2x2".into();
    config.train_size = 1024;
    // 1024 samples: epoch 0 at 8x4=32/step -> 32 steps; epoch 1 at
    // 16x4=64/step -> 16 steps. The phase boundary swaps every worker's
    // grad executable (batch-size control).
    config.batch = BatchSchedule::new(
        vec![
            Phase { from_epoch: 0, per_worker: 8, workers: 4 },
            Phase { from_epoch: 1, per_worker: 16, workers: 4 },
        ],
        2,
    );
    // `run()` bit-compares every rank's params/momenta/bn state against
    // rank 0 at each phase boundary and errors on divergence, so this
    // unwrap IS the bit-identical-replicas assertion.
    let report = Trainer::new(config).unwrap().run().unwrap();
    assert_eq!(report.summary.steps, 48);
    let batches: Vec<usize> = report.metrics.steps.iter().map(|s| s.global_batch).collect();
    assert!(batches.contains(&32), "phase 1 batches: {batches:?}");
    assert!(batches.contains(&64), "phase 2 missing: {batches:?}");
    // the switch happens exactly once, at the epoch boundary
    let switches = batches.windows(2).filter(|w| w[0] != w[1]).count();
    assert_eq!(switches, 1, "{batches:?}");
    // training continued sanely across the swap
    assert!(report.summary.last_loss.is_finite());
    assert!(report.summary.last_loss < report.summary.first_loss);
}

/// Regression for the loss-precision bug: the scalar step loss must ride
/// the FP32 BN-stat buffer, so the reported `loss_mean` matches an
/// FP32-only reduction even when gradients use the FP16 wire.
#[test]
fn reported_loss_is_fp32_even_on_the_fp16_wire() {
    let run = |wire: &str| {
        let mut c = base_config("it-loss-precision", 4, 1);
        c.grad_wire = wire.into();
        Trainer::new(c).unwrap().run().unwrap().metrics.steps[0].loss
    };
    let l16 = run("fp16");
    let l32 = run("fp32");
    // identical data and params at step 0: only the wire differs, and the
    // loss never touches it.
    assert!(
        (l16 - l32).abs() <= 1e-6,
        "fp16-wire loss {l16} vs fp32-wire loss {l32}"
    );
}

#[test]
fn collective_choice_does_not_change_numerics_much() {
    let run = |spec: &str| {
        let mut c = base_config("it-coll", 4, 12);
        c.collective = spec.into();
        c.grad_wire = "fp32".into();
        Trainer::new(c).unwrap().run().unwrap()
    };
    let torus = run("torus:2x2");
    let ring = run("ring");
    let hier = run("hierarchical:2");
    // identical data/seed; only reduction order differs (fp32 rounding)
    let t0 = torus.metrics.steps[0].loss;
    assert!((t0 - ring.metrics.steps[0].loss).abs() < 1e-5);
    assert!((t0 - hier.metrics.steps[0].loss).abs() < 1e-5);
    let tl = torus.summary.last_loss;
    assert!((tl - ring.summary.last_loss).abs() < 2e-2, "{tl} vs {}", ring.summary.last_loss);
    assert!((tl - hier.summary.last_loss).abs() < 2e-2);
}

#[test]
fn fp16_wire_tracks_fp32_training() {
    let run = |wire: &str| {
        let mut c = base_config("it-wire", 4, 12);
        c.grad_wire = wire.into();
        Trainer::new(c).unwrap().run().unwrap()
    };
    let h = run("fp16");
    let f = run("fp32");
    // same trajectory within fp16 quantisation noise
    assert!(
        (h.summary.last_loss - f.summary.last_loss).abs() < 1e-1,
        "fp16 {:.4} vs fp32 {:.4}",
        h.summary.last_loss,
        f.summary.last_loss
    );
}

#[test]
fn eval_beats_chance_after_training() {
    let mut config = base_config("it-eval", 4, 60);
    config.eval_batches = 8;
    let report = Trainer::new(config).unwrap().run().unwrap();
    let acc = report.final_eval.expect("final eval").accuracy;
    // 10 classes: chance = 10%; the synthetic task is easy
    assert!(acc > 0.15, "top-1 {:.1}% not above chance", acc * 100.0);
}

/// `eval_every` is a *step interval*: N means one evaluation after every
/// N-th global optimizer step, plus the final eval — which must not be
/// duplicated when the interval already landed on the last step.
#[test]
fn eval_every_is_a_step_interval() {
    // 12 steps, eval_every 4 -> evals at steps 4, 8, 12; the step-12 eval
    // doubles as the final eval (no duplicate).
    let mut config = base_config("it-evint", 4, 12);
    config.eval_every = 4;
    let report = Trainer::new(config).unwrap().run().unwrap();
    let steps: Vec<usize> = report.metrics.evals.iter().map(|e| e.step).collect();
    assert_eq!(steps, vec![4, 8, 12], "interval evals wrong: {steps:?}");
    assert_eq!(report.final_eval.expect("final eval").step, 12);

    // 12 steps, eval_every 5 -> interval evals at 5, 10, then the final
    // eval at 12 is appended.
    let mut config = base_config("it-evint5", 4, 12);
    config.eval_every = 5;
    let report = Trainer::new(config).unwrap().run().unwrap();
    let steps: Vec<usize> = report.metrics.evals.iter().map(|e| e.step).collect();
    assert_eq!(steps, vec![5, 10, 12], "interval+final evals wrong: {steps:?}");

    // eval_every 0 -> only the final eval.
    let mut config = base_config("it-evint0", 4, 12);
    config.eval_every = 0;
    let report = Trainer::new(config).unwrap().run().unwrap();
    assert_eq!(report.metrics.evals.len(), 1);
    assert_eq!(report.metrics.evals[0].step, 12);
}

/// The multi-lane compute pool must not change numerics: the same run
/// through one serialized lane and through one-lane-per-rank ends with
/// identical loss curves and byte-identical checkpoints — across a
/// batch-size-control phase switch that also *changes the worker count*
/// (exercising export → import of resident state between lane sets).
#[test]
fn multi_lane_pool_matches_single_lane_bitwise() {
    let dir = std::env::temp_dir().join(format!("fsgd-lanes-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let run = |lanes: usize, ckpt: &std::path::Path| {
        let mut c = base_config("it-lanes", 4, 24);
        c.train_size = 512;
        c.batch = BatchSchedule::new(
            vec![
                Phase { from_epoch: 0, per_worker: 8, workers: 4 },
                Phase { from_epoch: 1, per_worker: 16, workers: 2 },
            ],
            4,
        );
        c.compute_lanes = lanes;
        Trainer::new(c)
            .unwrap()
            .with_checkpoint(ckpt)
            .run()
            .unwrap()
    };
    let ck_serial = dir.join("serial.ckpt");
    let ck_pool = dir.join("pool.ckpt");
    let serial = run(1, &ck_serial);
    let pooled = run(0, &ck_pool);
    assert_eq!(serial.lanes, 1);
    assert_eq!(pooled.lanes, 4, "auto width = widest phase");
    let a: Vec<f64> = serial.metrics.steps.iter().map(|s| s.loss).collect();
    let b: Vec<f64> = pooled.metrics.steps.iter().map(|s| s.loss).collect();
    assert_eq!(a, b, "lane count changed the loss curve");
    assert_eq!(
        std::fs::read(&ck_serial).unwrap(),
        std::fs::read(&ck_pool).unwrap(),
        "lane count changed the final state bytes"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// The bucketed pipeline is a pure scheduling change: a `bucket_bytes = 0`
/// (single-bucket, serial) run and the default bucketed run share the same
/// forward numerics — identical step-0 loss — and track each other's
/// trajectory within reduction-chunking noise. Both uphold the per-phase
/// replica bit-identity invariant (`run()` aborts otherwise), which is the
/// acceptance suite for the overlap refactor.
#[test]
fn bucketed_pipeline_tracks_the_single_bucket_schedule() {
    let run = |bytes: usize| {
        let mut c = base_config("it-bucket", 4, 20);
        c.bucket_bytes = bytes;
        Trainer::new(c).unwrap().run().unwrap()
    };
    let serial = run(0);
    let bucketed = run(8192);
    assert_eq!(serial.summary.steps, bucketed.summary.steps);
    // step-0 loss comes out of the forward pass before any reduction —
    // bucketing cannot change it at all
    assert_eq!(
        serial.metrics.steps[0].loss, bucketed.metrics.steps[0].loss,
        "bucketing changed the forward pass"
    );
    // after 20 steps the trajectories differ only by fp16-wire chunking
    assert!(
        (serial.summary.last_loss - bucketed.summary.last_loss).abs() < 5e-2,
        "serial {:.4} vs bucketed {:.4}",
        serial.summary.last_loss,
        bucketed.summary.last_loss
    );
    // the serial schedule cannot hide comm behind backprop
    assert_eq!(serial.summary.mean_comm_hidden, 0.0);
}

/// Single-bucket runs are deterministic and lane-count-invariant down to
/// the checkpoint bytes — the serial path through the new streaming
/// machinery behaves exactly like a fixed schedule.
#[test]
fn single_bucket_schedule_is_bitwise_reproducible() {
    let dir = std::env::temp_dir().join(format!("fsgd-bucket0-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let run = |lanes: usize, ckpt: &std::path::Path| {
        let mut c = base_config("it-bucket0", 4, 12);
        c.bucket_bytes = 0;
        c.compute_lanes = lanes;
        Trainer::new(c)
            .unwrap()
            .with_checkpoint(ckpt)
            .run()
            .unwrap()
    };
    let ck_a = dir.join("a.ckpt");
    let ck_b = dir.join("b.ckpt");
    let a = run(1, &ck_a);
    let b = run(0, &ck_b);
    let la: Vec<f64> = a.metrics.steps.iter().map(|s| s.loss).collect();
    let lb: Vec<f64> = b.metrics.steps.iter().map(|s| s.loss).collect();
    assert_eq!(la, lb, "lane width changed the single-bucket loss curve");
    assert_eq!(
        std::fs::read(&ck_a).unwrap(),
        std::fs::read(&ck_b).unwrap(),
        "lane width changed the single-bucket checkpoint bytes"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn invalid_grid_is_a_clean_error() {
    let mut config = base_config("it-badgrid", 4, 5);
    config.collective = "torus:3x3".into(); // 9 != 4 ranks
    let err = Trainer::new(config).unwrap().run().unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("torus"), "unexpected error: {msg}");
}

#[test]
fn unknown_arch_fails_at_construction() {
    let mut config = base_config("it-badarch", 2, 2);
    config.arch = "resnet9000".into();
    assert!(Trainer::new(config).is_err());
}

#[test]
fn single_rank_degenerate_case_works() {
    let mut config = base_config("it-1rank", 1, 8);
    config.collective = "torus:1x1".into();
    let report = Trainer::new(config).unwrap().run().unwrap();
    assert_eq!(report.summary.steps, 8);
    assert!(report.summary.last_loss.is_finite());
}

#[test]
fn determinism_same_seed_same_curve() {
    let run = || {
        Trainer::new(base_config("it-det", 4, 8))
            .unwrap()
            .run()
            .unwrap()
            .metrics
            .steps
            .iter()
            .map(|s| s.loss)
            .collect::<Vec<f64>>()
    };
    let a = run();
    let b = run();
    assert_eq!(a, b, "same seed must give a bit-identical loss curve");
}

/// Checkpoint/resume determinism across a batch-size-control phase
/// boundary: train N steps straight vs. train k, checkpoint, resume,
/// train N−k. The reported losses must agree step for step AND the final
/// checkpoints must be byte-identical — params, momenta and `bn_running`
/// bit for bit (this exercises `PhaseCtx::skip_steps` and the loader
/// fast-forward path).
#[test]
fn checkpoint_resume_is_exactly_continuous() {
    let dir = std::env::temp_dir().join(format!("fsgd-it-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let mid = dir.join("mid.ckpt");
    let done_a = dir.join("done_a.ckpt");
    let done_b = dir.join("done_b.ckpt");

    // Two phases: epoch 0 runs 8 steps at 8/worker (256/32), later epochs
    // 4 steps at 16/worker. 16 total steps span the phase switch; the
    // resume point (step 7) sits mid-phase-1, so the resumed run must
    // fast-forward the loaders and then cross the boundary.
    let config = |name: &str, steps: usize| {
        let mut c = base_config(name, 4, steps);
        c.train_size = 256;
        c.batch = BatchSchedule::new(
            vec![
                Phase { from_epoch: 0, per_worker: 8, workers: 4 },
                Phase { from_epoch: 1, per_worker: 16, workers: 4 },
            ],
            8,
        );
        c
    };

    // Continuous 16-step run.
    let continuous = Trainer::new(config("it-cont", 16))
        .unwrap()
        .with_checkpoint(&done_a)
        .run()
        .unwrap();

    // 7 steps + save, then resume for the remaining 9.
    Trainer::new(config("it-part1", 7))
        .unwrap()
        .with_checkpoint(&mid)
        .run()
        .unwrap();
    let resumed = Trainer::new(config("it-part2", 16))
        .unwrap()
        .with_resume(&mid)
        .with_checkpoint(&done_b)
        .run()
        .unwrap();

    // The resumed run must reproduce steps 7..16 bit-for-bit.
    let cont_tail: Vec<(usize, f64)> = continuous
        .metrics
        .steps
        .iter()
        .skip(7)
        .map(|s| (s.step, s.loss))
        .collect();
    let res_all: Vec<(usize, f64)> = resumed
        .metrics
        .steps
        .iter()
        .map(|s| (s.step, s.loss))
        .collect();
    assert_eq!(res_all.len(), 9);
    assert_eq!(cont_tail, res_all);

    // Final params, momenta and bn_running agree bit for bit: the two
    // final checkpoints (self-describing tensors + run metadata) are
    // byte-identical.
    let bytes_a = std::fs::read(&done_a).unwrap();
    let bytes_b = std::fs::read(&done_b).unwrap();
    assert_eq!(
        bytes_a, bytes_b,
        "straight vs resumed runs must end in byte-identical checkpoints"
    );

    // resuming past the end is a clean error
    let err = Trainer::new(config("it-past", 16))
        .unwrap()
        .with_resume(&done_a)
        .run()
        .unwrap_err();
    assert!(format!("{err:#}").contains("end of this schedule"));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn halving_doubling_collective_trains_too() {
    let mut config = base_config("it-hd", 4, 10);
    config.collective = "halving-doubling".into();
    let report = Trainer::new(config).unwrap().run().unwrap();
    assert!(report.summary.last_loss < report.summary.first_loss);
}

#[test]
fn config_b_momentum_applied_from_schedule() {
    let mut config = base_config("it-cfgb", 4, 6);
    config.lr = LrSchedule::ConfigB {
        warmup_epochs: 1.0,
        warmup_start: 0.1,
        base_low: 1.0,
        base_high: 2.0,
        switch_epoch: 3.0,
        total_epochs: 8.0,
    };
    let report = Trainer::new(config).unwrap().run().unwrap();
    // global batch 32 << 32K reference -> momentum clamps to 0.0
    for s in &report.metrics.steps {
        assert_eq!(s.momentum, 0.0);
        assert!(s.lr > 0.0 && s.lr < 1.0);
    }
}
