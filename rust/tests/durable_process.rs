//! Coordinator crash/resume, end to end over real OS processes: a durable
//! cluster's coordinator is SIGKILLed mid-run, the orphaned workers hold
//! in their `fault.coordinator_grace_ms` window and re-dial, a fresh
//! `flashsgd coordinator --resume <dir>` replays the run journal plus the
//! newest snapshot — and the final checkpoint must be **byte-identical**
//! to an undisturbed memory-mode run's.
//!
//! This is the durability tentpole's acceptance test. It drives the real
//! binary (`CARGO_BIN_EXE_flashsgd`), the real control socket, the real
//! write-ahead journal and snapshot files on disk, and a real `kill -9`.

use std::io::Read;
use std::process::{Child, Command, Stdio};
use std::thread;
use std::time::{Duration, Instant};

const BIN: &str = env!("CARGO_BIN_EXE_flashsgd");
const N_WORKERS: usize = 4;

// Distinct from rejoin_process.rs's 7093-7096 so the two process suites
// can never collide on a lingering socket.
const BIND: &str = "127.0.0.1:7097";
const HTTP: &str = "127.0.0.1:7098";

/// Two phases; the boundary between them is where the snapshot lands.
/// Phase 1 is a full two epochs (24 steps) so the SIGKILL — fired the
/// moment the boundary snapshot appears on disk — lands mid-phase.
fn config_text(snap_dir: Option<&std::path::Path>) -> String {
    let durable = match snap_dir {
        Some(dir) => format!(
            "\n[checkpoint]\nevery_steps = 0\nkeep_last = 2\ndir = \"{}\"\n",
            dir.display()
        ),
        None => String::new(),
    };
    format!(
        r#"
name = "durable-smoke"
arch = "tiny"
collective = "torus:2x2"
grad_wire = "fp16"
label_smoothing = 0.1
weight_decay = 5e-5
seed = 11
epochs = 3
train_size = 384
eval_every = 0
eval_batches = 2
bucket_bytes = 8192

[lr]
kind = "const"
value = 1.0
momentum = 0.9

[batch]
phases = [[0, 4, 4], [1, 8, 4]]

[transport]
mode = "tcp"
bind = "{BIND}"
http = "{HTTP}"

[fault]
enabled = true
heartbeat_interval_ms = 50
rank_timeout_ms = 10000
max_restarts = 3
rejoin_grace_ms = 20000
coordinator_grace_ms = 120000
{durable}"#
    )
}

fn spawn_worker() -> Child {
    Command::new(BIN)
        .args(["worker", "--join", BIND])
        .stdout(Stdio::null())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawning a worker process")
}

fn spawn_coordinator(cfg: &std::path::Path, ckpt: &std::path::Path, resume: Option<&std::path::Path>) -> Child {
    let mut args = vec![
        "coordinator".to_string(),
        "--config".into(),
        cfg.to_str().unwrap().into(),
        "--save".into(),
        ckpt.to_str().unwrap().into(),
    ];
    if let Some(dir) = resume {
        args.push("--resume".into());
        args.push(dir.to_str().unwrap().into());
    }
    Command::new(BIN)
        .args(&args)
        .stdout(Stdio::null())
        .stderr(Stdio::piped())
        .spawn()
        .expect("spawning the coordinator")
}

/// First `snap-*.ckpt` visible in the durable dir, if any.
fn snapshot_on_disk(dir: &std::path::Path) -> Option<String> {
    let entries = std::fs::read_dir(dir).ok()?;
    entries
        .filter_map(|e| e.ok())
        .map(|e| e.file_name().to_string_lossy().into_owned())
        .find(|n| n.starts_with("snap-") && n.ends_with(".ckpt"))
}

/// Bounded wait for a process; panics (after killing the stragglers) if
/// the deadline passes, so a wedged cluster fails CI instead of hanging.
fn wait_bounded(coord: &mut Child, workers: &mut [Child], secs: u64) -> std::process::ExitStatus {
    let deadline = Instant::now() + Duration::from_secs(secs);
    loop {
        match coord.try_wait().expect("polling the coordinator") {
            Some(st) => return st,
            None if Instant::now() > deadline => {
                let _ = coord.kill();
                for w in workers.iter_mut() {
                    let _ = w.kill();
                }
                panic!("coordinator did not finish within {secs}s");
            }
            None => thread::sleep(Duration::from_millis(50)),
        }
    }
}

fn reap(workers: &mut [Child]) {
    for w in workers.iter_mut() {
        let deadline = Instant::now() + Duration::from_secs(30);
        loop {
            match w.try_wait() {
                Ok(Some(_)) => break,
                _ if Instant::now() > deadline => {
                    let _ = w.kill();
                    let _ = w.wait();
                    break;
                }
                _ => thread::sleep(Duration::from_millis(20)),
            }
        }
    }
}

fn drain_stderr(child: &mut Child) -> String {
    let mut s = String::new();
    if let Some(mut pipe) = child.stderr.take() {
        let _ = pipe.read_to_string(&mut s);
    }
    s
}

#[test]
fn sigkilled_coordinator_resumes_byte_identical() {
    let dir = std::env::temp_dir().join(format!("flashsgd-durable-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let snaps = dir.join("snaps");

    // Undisturbed baseline: the same schedule in memory mode (the `train`
    // subcommand ignores [transport]; no [checkpoint] section, so no
    // journal exists to collide with the cluster's).
    let cfg_base = dir.join("base.toml");
    std::fs::write(&cfg_base, config_text(None)).unwrap();
    let base_ckpt = dir.join("base.ckpt");
    let st = Command::new(BIN)
        .args([
            "train",
            "--config",
            cfg_base.to_str().unwrap(),
            "--save",
            base_ckpt.to_str().unwrap(),
        ])
        .stdout(Stdio::null())
        .stderr(Stdio::null())
        .status()
        .expect("running the memory-mode baseline");
    assert!(st.success(), "baseline run failed");

    // Durable cluster: coordinator + 4 workers, journal + snapshots on.
    let cfg = dir.join("durable.toml");
    std::fs::write(&cfg, config_text(Some(&snaps))).unwrap();
    let final_ckpt = dir.join("resumed.ckpt");
    let mut coord = spawn_coordinator(&cfg, &final_ckpt, None);
    let mut workers: Vec<Child> = (0..N_WORKERS).map(|_| spawn_worker()).collect();

    // Pull the plug the moment the phase-boundary snapshot is durable on
    // disk: phase 1 (24 steps) has only just started, so the kill lands
    // mid-phase with real progress in the journal behind it.
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        assert!(
            Instant::now() < deadline,
            "no snapshot ever appeared in {}",
            snaps.display()
        );
        if snapshot_on_disk(&snaps).is_some() {
            break;
        }
        thread::sleep(Duration::from_millis(5));
    }
    assert!(
        coord.try_wait().expect("polling the coordinator").is_none(),
        "coordinator finished before the kill — lengthen the schedule"
    );
    coord.kill().expect("SIGKILLing the coordinator");
    let _ = coord.wait();

    // The orphaned workers are now inside their 120 s coordinator_grace
    // window, re-dialing the join address. Restart the coordinator with
    // --resume: it replays the journal, restores the newest snapshot,
    // re-registers the held workers, and finishes the run.
    let mut coord2 = spawn_coordinator(&cfg, &final_ckpt, Some(&snaps));
    let status = wait_bounded(&mut coord2, &mut workers, 300);
    reap(&mut workers);
    let stderr = drain_stderr(&mut coord2);
    assert!(
        status.success(),
        "resumed coordinator failed; stderr:\n{stderr}"
    );
    assert!(
        stderr.contains("[resume] restored snapshot"),
        "the resume never restored a snapshot; stderr:\n{stderr}"
    );

    // The invariant the whole subsystem exists for: a SIGKILL-and-resume
    // run ends bit-identical to one that was never disturbed.
    let base = std::fs::read(&base_ckpt).expect("baseline checkpoint");
    let resumed = std::fs::read(&final_ckpt).expect("resumed checkpoint");
    assert_eq!(
        base, resumed,
        "crash/resume changed the final checkpoint: the replay did not \
         restore the boundary state (or the journal/snapshot pipeline \
         fed resume the wrong position)"
    );

    let _ = std::fs::remove_dir_all(&dir);
}
