//! Tag-window conformance: `Collective::tag_span` must really bound the
//! tags each algorithm puts on the wire, and two back-to-back collectives
//! offset by exactly `tag_span` on the same mesh must not cross-talk.
//!
//! This pins down the hand-derived spans (notably `torus2d`'s
//! `t_scatter`/`t_vertical`/`t_gather` layout, which was never checked
//! against actual usage before): if an algorithm ever used a tag at or
//! beyond its declared span, the window assertion fires; if two adjacent
//! windows overlapped in practice, the second reduction would consume the
//! first one's messages and the sums (or the run itself — a mismatched
//! receive blocks forever) would go wrong.

use std::sync::Arc;
use std::thread;

use flashsgd::collectives::{by_name, Collective, Mesh, Wire};

/// Deterministic per-rank vector for the first reduction.
fn vec_a(rank: usize, elems: usize) -> Vec<f32> {
    (0..elems)
        .map(|i| ((rank + 1) as f32 * 0.37 + i as f32 * 0.011).sin() * 0.5)
        .collect()
}

/// A different deterministic vector for the second reduction, so
/// cross-talk between the two windows cannot cancel out.
fn vec_b(rank: usize, elems: usize) -> Vec<f32> {
    (0..elems)
        .map(|i| ((rank + 2) as f32 * 0.71 - i as f32 * 0.023).cos() * 0.25 + 1.0)
        .collect()
}

fn expected(n: usize, elems: usize, gen: fn(usize, usize) -> Vec<f32>) -> Vec<f32> {
    let mut acc = vec![0.0f32; elems];
    for r in 0..n {
        for (a, v) in acc.iter_mut().zip(gen(r, elems)) {
            *a += v;
        }
    }
    acc
}

fn assert_close(got: &[f32], want: &[f32], what: &str) {
    assert_eq!(got.len(), want.len());
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!(
            (g - w).abs() <= 1e-3 + w.abs() * 1e-3,
            "{what}: elem {i}: got {g}, want {w}"
        );
    }
}

/// The algorithms × world sizes under test.
fn cases() -> Vec<(&'static str, usize)> {
    vec![
        ("ring", 4),
        ("ring", 6),
        ("halving-doubling", 8),
        ("hierarchical:2", 8),
        ("hierarchical:4", 8),
        ("torus:2x2", 4),
        ("torus:4x2", 8),
        ("torus:2x4", 8),
        ("torus:3x3", 9),
    ]
}

#[test]
fn single_all_reduce_stays_inside_the_declared_tag_window() {
    for (spec, n) in cases() {
        let coll: Arc<dyn Collective> = Arc::from(by_name(spec, n).unwrap());
        let span = coll.tag_span(n);
        assert!(span > 0, "{spec}: span must be positive");
        let eps = Mesh::new(n);
        let counters = eps[0].counters_arc();
        let handles: Vec<_> = eps
            .into_iter()
            .map(|mut ep| {
                let coll = coll.clone();
                thread::spawn(move || {
                    let mut buf = vec_a(ep.rank(), 97);
                    coll.all_reduce(&mut ep, &mut buf, Wire::F32, 0).unwrap();
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let max = counters.max_tag_seen();
        assert!(
            max < span,
            "{spec} over {n} ranks used tag {max}, but tag_span claims {span}"
        );
    }
}

/// The torus span is not merely an upper bound — it is **tight**: the
/// highest tag actually used is `span - 1` on every grid shape class
/// (x>1&y>1, single row, single column, asymmetric both ways). Tightness
/// matters because the bucketed gradient pipeline stacks one full span
/// per bucket per step; a slack span would waste tag space on every
/// bucket. The Table-4 shapes (too many ranks to run as threads) are
/// covered analytically by `torus2d::tests::tag_span_is_tight_for_table4_grids`
/// — the same packed-window formula verified here against real traffic.
#[test]
fn torus_tag_span_is_tight_on_the_wire() {
    for (x, y) in [(2usize, 2usize), (4, 2), (2, 4), (3, 3), (1, 4), (4, 1), (3, 5)] {
        let n = x * y;
        let coll: Arc<dyn Collective> = Arc::from(by_name(&format!("torus:{x}x{y}"), n).unwrap());
        let span = coll.tag_span(n);
        let eps = Mesh::new(n);
        let counters = eps[0].counters_arc();
        let handles: Vec<_> = eps
            .into_iter()
            .map(|mut ep| {
                let coll = coll.clone();
                thread::spawn(move || {
                    let mut buf = vec_a(ep.rank(), 151);
                    coll.all_reduce(&mut ep, &mut buf, Wire::F32, 0).unwrap();
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(
            counters.max_tag_seen(),
            span - 1,
            "torus:{x}x{y}: declared span {span} is not tight"
        );
    }
}

#[test]
fn back_to_back_windows_offset_by_tag_span_do_not_cross_talk() {
    for (spec, n) in cases() {
        let coll: Arc<dyn Collective> = Arc::from(by_name(spec, n).unwrap());
        let span = coll.tag_span(n);
        let elems = 193usize;
        let eps = Mesh::new(n);
        let counters = eps[0].counters_arc();
        let handles: Vec<_> = eps
            .into_iter()
            .map(|mut ep| {
                let coll = coll.clone();
                thread::spawn(move || {
                    let rank = ep.rank();
                    // Two reductions straight after one another — exactly
                    // how the worker loop spaces its grad and BN windows.
                    let mut a = vec_a(rank, elems);
                    coll.all_reduce(&mut ep, &mut a, Wire::F32, 0).unwrap();
                    let mut b = vec_b(rank, elems);
                    coll.all_reduce(&mut ep, &mut b, Wire::F32, span).unwrap();
                    (a, b)
                })
            })
            .collect();
        let results: Vec<(Vec<f32>, Vec<f32>)> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();

        // Both windows fit inside [0, 2*span).
        let max = counters.max_tag_seen();
        assert!(
            max < 2 * span,
            "{spec}: tag {max} escaped the second window (span {span})"
        );

        // Both reductions produced the exact sums on every rank.
        let want_a = expected(n, elems, vec_a);
        let want_b = expected(n, elems, vec_b);
        for (rank, (a, b)) in results.iter().enumerate() {
            assert_close(a, &want_a, &format!("{spec} rank {rank} first reduce"));
            assert_close(b, &want_b, &format!("{spec} rank {rank} second reduce"));
        }
        for (a, b) in &results[1..] {
            assert_eq!(a, &results[0].0, "{spec}: ranks disagree on first reduce");
            assert_eq!(b, &results[0].1, "{spec}: ranks disagree on second reduce");
        }
    }
}
