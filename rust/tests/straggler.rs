//! Straggler-defense tests: telemetry, graceful demotion, and the
//! no-perturbation guarantees.
//!
//! The injected slowdown ([`InjectedFault::slow_at`]) is a pure
//! `thread::sleep` in the step loop — it never touches numerics — so every
//! scenario here has a bit-identity oracle:
//!
//!   * a chronically *slow but advancing* rank must survive
//!     `fault.rank_timeout` (the false-positive fix: step progress in the
//!     heartbeat telemetry suppresses the death sentence while the rank is
//!     provably advancing),
//!   * under `policy = "demote"` with a rejoin grace the straggler is
//!     confirmed, recorded in [`TrainReport::demotions`] and readmitted at
//!     the same boundary — so the final checkpoint stays byte-identical to
//!     an undisturbed run's,
//!   * the demotion decision is seeded/deterministic: two identical runs
//!     demote the same rank at the same phase boundaries,
//!   * detection enabled with no straggler present changes nothing:
//!     checkpoints are byte-identical to the subsystem being off.

use std::time::Duration;

use flashsgd::config::{FaultConfig, InjectedFault, StragglerPolicy, TrainConfig};
use flashsgd::coordinator::Trainer;
use flashsgd::sched::{BatchSchedule, LrSchedule};

fn base_config(name: &str, ranks: usize, steps: usize) -> TrainConfig {
    TrainConfig {
        name: name.into(),
        arch: "tiny".into(),
        collective: "torus".into(),
        grad_wire: "fp16".into(),
        label_smoothing: 0.1,
        lr: LrSchedule::Const { lr: 0.5, momentum: 0.9 },
        batch: BatchSchedule::constant(8, ranks, 8),
        weight_decay: 5e-5,
        seed: 7,
        max_steps: steps,
        eval_every: 0,
        eval_batches: 4,
        train_size: 2048,
        compute_lanes: 0,
        bucket_bytes: 8192,
        fault: FaultConfig::default(),
        transport: flashsgd::config::TransportConfig::default(),
        checkpoint: flashsgd::config::CheckpointConfig::default(),
    }
}

/// Train `cfg` with a checkpoint and return (report, checkpoint bytes).
fn run_with_ckpt(cfg: TrainConfig, dir: &std::path::Path) -> (flashsgd::coordinator::TrainReport, Vec<u8>) {
    let ckpt = dir.join(format!("{}.ckpt", cfg.name));
    let report = Trainer::new(cfg).unwrap().with_checkpoint(&ckpt).run().unwrap();
    (report, std::fs::read(&ckpt).unwrap())
}

/// The heartbeat false-positive fix: a rank sleeping far past
/// `rank_timeout` every step — but completing steps, with its telemetry
/// showing the pace — must NOT be declared dead. Pre-fix, staleness alone
/// was a death sentence and this run would burn a recovery (or die).
#[test]
fn slow_but_advancing_rank_survives_rank_timeout() {
    let dir = std::env::temp_dir().join(format!("fsgd-slow-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();

    let mut cfg = base_config("slow-advancing", 4, 6);
    cfg.fault.heartbeat_interval = Duration::from_millis(25);
    cfg.fault.rank_timeout = Duration::from_millis(400);
    // Every step, rank 1 sleeps 600 ms — 1.5× the rank timeout. Its beats
    // go stale mid-step, but its completed-step telemetry keeps advancing.
    cfg.fault.inject = Some(InjectedFault::slow_at(1, 0, 600));
    let (report, slow_bytes) = run_with_ckpt(cfg, &dir);
    assert_eq!(report.summary.steps, 6);
    assert!(
        report.recoveries.is_empty(),
        "a slow-but-advancing rank must not be declared dead: {:?}",
        report.recoveries
    );
    assert!(report.demotions.is_empty(), "policy observe never demotes");

    // The slowdown is a pure sleep and the default policy is observe-only:
    // the run must be byte-identical to an undisturbed run with the whole
    // fault subsystem off.
    let mut clean = base_config("slow-advancing-clean", 4, 6);
    clean.fault = FaultConfig::disabled();
    let (_, clean_bytes) = run_with_ckpt(clean, &dir);
    assert_eq!(
        slow_bytes, clean_bytes,
        "observe-policy telemetry must be a zero-numerics-impact feature"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// Straggler config for the demotion tests: judge after 3 steps, confirm
/// immediately (zero grace), demote.
fn demote_fault(slow_rank: usize, millis: u64) -> FaultConfig {
    let mut f = FaultConfig::default();
    f.heartbeat_interval = Duration::from_millis(10);
    f.rank_timeout = Duration::from_secs(10);
    // Readmit-at-the-boundary mode: the demotion is recorded but the world
    // keeps its width, so the run's numerics never change.
    f.rejoin_grace = Duration::from_secs(20);
    f.straggler.policy = StragglerPolicy::Demote;
    f.straggler.slow_factor = 2.0;
    f.straggler.min_samples = 3;
    f.straggler.grace = Duration::ZERO;
    f.inject = Some(InjectedFault::slow_at(slow_rank, 0, 100));
    f
}

/// Under `policy = "demote"` with a rejoin grace: the seeded slow rank is
/// confirmed and recorded, the drain happens at a phase boundary (no
/// mid-collective abort, no restart burned), and because the rank is
/// readmitted on the spot the final checkpoint is byte-identical to an
/// undisturbed run's.
#[test]
fn demoted_straggler_is_recorded_at_a_boundary_and_checkpoint_unchanged() {
    let dir = std::env::temp_dir().join(format!("fsgd-demote-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();

    // 16 steps = two 8-step phases; rank 1 sleeps 400 ms/step — far past
    // 2× a debug-mode tiny-arch step — so its local-work EWMA crosses the
    // threshold within `min_samples` steps of each phase.
    let mut cfg = base_config("demote-grace", 4, 16);
    cfg.fault = demote_fault(1, 400);
    let (report, demoted_bytes) = run_with_ckpt(cfg, &dir);
    assert_eq!(report.summary.steps, 16);
    assert!(
        report.recoveries.is_empty(),
        "demotion must not burn the restart budget: {:?}",
        report.recoveries
    );
    assert!(
        !report.demotions.is_empty(),
        "the seeded straggler must be confirmed and recorded"
    );
    for d in &report.demotions {
        assert_eq!(d.rank, 1, "only the seeded slow rank may be demoted");
        assert!(d.readmitted && !d.evicted, "grace mode readmits in place");
        // drained at a phase boundary: step 8 or 16, never mid-phase
        assert!(
            d.phase_first_step == 8 || d.phase_first_step == 16,
            "demotion at step {} is not a phase boundary",
            d.phase_first_step
        );
        assert!(
            d.step_ms_ewma > d.median_ms,
            "a demoted rank must be over the median ({} vs {})",
            d.step_ms_ewma,
            d.median_ms
        );
    }

    // Byte-identity oracle: the sleep never touched numerics and the
    // readmission kept the width, so the checkpoint matches a run with the
    // fault subsystem off entirely.
    let mut clean = base_config("demote-grace-clean", 4, 16);
    clean.fault = FaultConfig::disabled();
    let (_, clean_bytes) = run_with_ckpt(clean, &dir);
    assert_eq!(
        demoted_bytes, clean_bytes,
        "demote+rejoin_grace must keep the final checkpoint byte-identical"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// Seeded determinism: the same config produces the same demotion decision
/// — same rank, same phase boundaries — run after run. (The EWMA values
/// are wall-clock and may wiggle; the *decision* may not.)
#[test]
fn seeded_slowdown_demotes_deterministically() {
    let dir = std::env::temp_dir().join(format!("fsgd-det-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();

    let run = |name: &str| {
        let mut cfg = base_config(name, 4, 16);
        cfg.fault = demote_fault(1, 400);
        let (report, bytes) = run_with_ckpt(cfg, &dir);
        let decisions: Vec<(usize, usize, bool, bool)> = report
            .demotions
            .iter()
            .map(|d| (d.rank, d.phase_first_step, d.evicted, d.readmitted))
            .collect();
        (decisions, bytes)
    };
    let (first, bytes_a) = run("det-a");
    let (second, bytes_b) = run("det-b");
    assert!(!first.is_empty(), "the seeded straggler must be demoted");
    assert_eq!(first, second, "same seed, same config => same demotions");
    assert_eq!(bytes_a, bytes_b, "and bit-identical training output");
    std::fs::remove_dir_all(&dir).ok();
}

/// Detection armed but nothing slow: the straggler machinery must be
/// invisible — no demotions, and training output bit-identical to the
/// whole fault subsystem being off.
#[test]
fn armed_detection_with_no_straggler_is_bit_identical() {
    let dir = std::env::temp_dir().join(format!("fsgd-nostrag-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();

    let mut armed = base_config("armed", 4, 10);
    armed.fault = demote_fault(1, 400);
    armed.fault.inject = None; // armed, but nobody is slow
    let (report, armed_bytes) = run_with_ckpt(armed, &dir);
    assert!(
        report.demotions.is_empty(),
        "a healthy cluster must never be demoted: {:?}",
        report.demotions
    );
    assert!(report.recoveries.is_empty());

    let mut off = base_config("armed-off", 4, 10);
    off.fault = FaultConfig::disabled();
    let (_, off_bytes) = run_with_ckpt(off, &dir);
    assert_eq!(
        armed_bytes, off_bytes,
        "armed straggler detection must be a zero-numerics-impact feature"
    );
    std::fs::remove_dir_all(&dir).ok();
}
