#!/usr/bin/env bash
# Record the two perf baselines into BENCH_pipeline.json and
# BENCH_collectives.json at the repo root.
#
# Run this from a machine with the Rust toolchain, ideally idle, and
# commit the refreshed JSON alongside any perf-affecting change. The
# checked-in files start life as `"recorded": false` sentinels; this
# script is the only sanctioned way to turn them into numbers.
#
# Usage: tools/record_baselines.sh
set -euo pipefail

root="$(cd "$(dirname "$0")/.." && pwd)"
cd "$root"

echo "== building benches (release) =="
cargo bench --no-run

# Each bench writes its JSON into the current working directory; run
# from the repo root so the baselines land next to this script's parent.
echo "== step_pipeline =="
cargo bench --bench step_pipeline

echo "== collectives_micro =="
cargo bench --bench collectives_micro

for f in BENCH_pipeline.json BENCH_collectives.json; do
    test -s "$f" || { echo "error: $f was not written" >&2; exit 1; }
    grep -q '"recorded": *true' "$f" || {
        echo "error: $f is still a sentinel (recorded != true)" >&2
        exit 1
    }
done

echo
echo "Baselines recorded:"
ls -l BENCH_pipeline.json BENCH_collectives.json
echo "Review the diffs, then commit both files."
